"""Shared benchmark helpers."""
from __future__ import annotations

import time


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time in microseconds of fn(*args) (jit-compatible:
    blocks on result)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
