"""Paper-figure reproductions driven by the synthetic fleet.

- Fig 7  : day-ahead APE distributions (forecast quality)
- Fig 3/8: single-cluster load shaping (VCC vs carbon intensity)
- Fig 9-11: cluster regimes X (predictable) / Y (uncertain) / Z (small flex)
- Fig 12 : randomized controlled experiment — power drop in peak-carbon
           hours on treated vs control cluster-days (paper: 1-2%)
- [20]   : PD power-model MAPE (<5% for >95% of PDs)
- §III-B3: carbon-forecast MAPE band (0.4% - 26%)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import carbon, fleet as F, power, slo


def _fleet(n_clusters=16, days=10, seed=1, lambda_e=0.5):
    cfg = F.FleetConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                        lambda_e=lambda_e, seed=seed)
    st = F.init_fleet(cfg)
    recs = []
    for _ in range(days):
        rec = {}
        t0 = time.perf_counter()
        st = F.day_cycle(st, rec)
        rec["wall_s"] = time.perf_counter() - t0
        recs.append(rec)
    return cfg, st, recs


def fig7_forecast_ape(st, recs):
    """APE of day-ahead U_IF / T_UF / T_R forecasts on operating days."""
    rows = []
    uif_apes, tuf_apes, tr_apes = [], [], []
    for rec in recs:
        fc = rec["fc"]
        res = rec["result"]
        act_uif = np.asarray(st.hist_uif[:, -1])  # imperfect but recent
        uif_apes.append(np.abs(np.asarray(fc["uif"]) - act_uif)
                        / np.clip(act_uif, 1e-6, None))
        tuf_apes.append(np.abs(np.asarray(fc["tuf"])
                               - np.asarray(res.served))
                        / np.clip(np.asarray(res.served), 1e-6, None))
        tr_apes.append(np.abs(np.asarray(fc["tr"])
                              - np.asarray(res.reservations.sum(1)))
                       / np.clip(np.asarray(res.reservations.sum(1)),
                                 1e-6, None))
    uif = np.stack(uif_apes)       # (days, n, 24)
    med_uif = np.median(uif, axis=(0, 2))
    frac_uif = (med_uif < 0.10).mean()
    med_tuf = np.median(np.stack(tuf_apes), axis=0)
    med_tr = np.median(np.stack(tr_apes), axis=0)
    rows.append(("fig7_uif_median_ape_lt10pct_clusters", frac_uif,
                 f"paper: >0.9; median APE={np.median(med_uif):.3f}"))
    rows.append(("fig7_tr_median_ape", float(np.median(med_tr)),
                 "paper: <10% for >90% clusters"))
    rows.append(("fig7_tuf_median_ape", float(np.median(med_tuf)),
                 "paper: flexible noisier than inflexible"))
    return rows


def fig3_load_shaping(st, recs):
    """Shaped clusters: flexible load moved out of peak-carbon hours."""
    moved, corr = [], []
    for rec in recs:
        sol, eta = rec["sol"], rec["intensity"]
        for c in np.nonzero(np.asarray(sol.shaped))[0]:
            d = np.asarray(sol.delta[c])
            if d.std() < 1e-6:
                continue
            moved.append(0.5 * np.abs(d).sum() / 24.0)
            corr.append(np.corrcoef(d, np.asarray(eta[c]))[0, 1])
    return [("fig3_flex_fraction_shifted", float(np.mean(moved)),
             "fraction of daily flexible usage moved between hours"),
            ("fig3_delta_carbon_corr", float(np.mean(corr)),
             "expect strongly negative (shift away from dirty hours)")]


def fig9_11_cluster_regimes(st, recs):
    """VCC headroom vs load: predictable vs uncertain vs small-flex.
    X = shaped cluster with the LEAST headroom (tight forecasts),
    Y = shaped cluster with the most headroom among meaningfully-shaped
    ones (uncertain forecasts inflate the VCC), Z = smallest flexible
    share. Headroom is capped to exclude capacity-VCC (unshaped) rows."""
    rec = recs[-1]
    sol, res = rec["sol"], rec["result"]
    vcc = np.asarray(rec["vcc"])
    demand = np.asarray(res.reservations)
    headroom = vcc.sum(1) / np.clip(demand.sum(1), 1e-6, None) - 1.0
    flex_share = np.asarray(res.usage_flex.sum(1)) \
        / np.clip(np.asarray(res.usage_total.sum(1)), 1e-6, None)
    delta_active = np.asarray(jnp.std(sol.delta, axis=1)) > 1e-4
    shaped = np.asarray(sol.shaped) & delta_active & (headroom < 2.0) \
        & (headroom > 0.0)
    if not shaped.any():
        shaped = np.asarray(sol.shaped)
    x = int(np.argmin(np.where(shaped, headroom, np.inf)))
    y = int(np.argmax(np.where(shaped, headroom, -np.inf)))
    z = int(np.argmin(flex_share))
    out = []
    for label, c, note in (("X_predictable", x, "paper: VCC ~18% above "
                            "load, sustained midday drop"),
                           ("Y_uncertain", y, "paper: VCC ~33% above load, "
                            "shorter drop"),
                           ("Z_small_flex", z, "paper: no meaningful "
                            "shaping")):
        drop = 0.0
        eta = np.asarray(rec["intensity"][c])
        dirty = eta >= np.quantile(eta, 0.75)
        use = np.asarray(res.usage_flex[c])
        if use.mean() > 1e-6:
            drop = 1.0 - use[dirty].mean() / max(use.mean(), 1e-9)
        out.append((f"fig9_11_{label}_headroom", float(headroom[c]),
                    f"flex_drop_dirty_hours={drop:.2f}; {note}"))
    return out


def fig12_controlled_experiment(n_clusters=16, days=12, seed=5):
    """Randomized cluster-day treatment; compare mean normalized power in
    the top-carbon hours of treated vs control."""
    cfg = F.FleetConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                        lambda_e=0.8, seed=seed)
    st = F.init_fleet(cfg)
    rng = np.random.RandomState(0)
    treated_power, control_power = [], []
    for d in range(days):
        treat = jnp.asarray(rng.rand(n_clusters) < 0.5)
        # shape only the treated clusters this day
        power_fn, slope_fn, _ = F.make_power_fn(st)
        fc = F.day_forecasts(st)
        _, _, eta_act, eta_fc = F.carbon_forecast_next(st, st.day)
        prob = F.build_problem(st, fc, eta_fc, power_fn, slope_fn)
        from repro.core import vcc as V
        sol = V.solve_vcc(prob)
        gate = st.shaping_allowed & sol.shaped & treat
        vcc_curve = jnp.where(gate[:, None], sol.vcc,
                              st.capacity[:, None] * 10.0)
        st.hist_tr_pred = jnp.concatenate(
            [st.hist_tr_pred[:, 1:], fc["tr"][:, None]], axis=1)
        st.hist_uif_pred = jnp.concatenate(
            [st.hist_uif_pred[:, 1:], fc["uif"][:, None]], axis=1)
        st, res, intensity = F._observe_day(st, st.day, True, vcc_curve,
                                            collect=True)
        new_slo, allowed = slo.update(st.slo_state, cfg.slo,
                                      res.reservations.sum(1),
                                      vcc_curve.sum(1), res.unmet,
                                      res.arrived)
        st.slo_state, st.shaping_allowed = new_slo, allowed
        p = np.asarray(res.power)
        e = np.asarray(intensity)
        pn = p / p.mean(axis=1, keepdims=True)        # normalized power
        dirty = e >= np.quantile(e, 0.75, axis=1, keepdims=True)
        for c in range(n_clusters):
            val = pn[c][dirty[c]].mean()
            (treated_power if bool(treat[c]) else control_power).append(val)
    t, c = np.mean(treated_power), np.mean(control_power)
    drop_pct = (c - t) / c * 100.0
    return [("fig12_peak_carbon_power_drop_pct", float(drop_pct),
             f"paper: 1-2%; treated={t:.4f} control={c:.4f} "
             f"n=({len(treated_power)},{len(control_power)})")]


def power_model_mape(seed=0, n_pd=64):
    key = jax.random.PRNGKey(seed)
    truth = power.PDTruth(
        idle_kw=60 + 40 * jax.random.uniform(jax.random.fold_in(key, 1),
                                             (n_pd,)),
        slope_kw=250 + 150 * jax.random.uniform(jax.random.fold_in(key, 2),
                                                (n_pd,)),
        curve=0.8 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 3),
                                             (n_pd,)))
    cpu = 0.15 + 0.7 * jax.random.uniform(jax.random.fold_in(key, 4),
                                          (n_pd, 24 * 28))
    pw = power.simulate_pd_power(jax.random.fold_in(key, 5), truth, cpu)
    coef, breaks = power.fit_pd_models(cpu, pw)
    mapes = np.asarray(power.daily_mape_b(coef, breaks, cpu, pw))
    return [("power_model_pd_mape_lt5pct", float((mapes < 0.05).mean()),
             f"paper [20]: >0.95; worst={mapes.max():.4f}")]


def carbon_forecast_mape(days=40):
    zones = carbon.default_zones(6)
    mapes = []
    for i, z in enumerate(zones):
        key = jax.random.PRNGKey(100 + i)
        hist = carbon.simulate_zone(key, z, days)
        ms = []
        for d in range(days - 8, days - 1):
            fc = carbon.forecast_day_ahead(jax.random.fold_in(key, d),
                                           hist[:d], hist[d],
                                           z.weather_vol * 0.15)
            ms.append(float(carbon.mape(fc, hist[d])))
        mapes.append(np.mean(ms))
    return [("carbon_forecast_mape_min", float(np.min(mapes)),
             "paper band: 0.4%-26%"),
            ("carbon_forecast_mape_max", float(np.max(mapes)),
             f"zones={['%.3f' % m for m in mapes]}")]


def run():
    rows = []
    cfg, st, recs = _fleet()
    cyc = np.mean([r["wall_s"] for r in recs])
    rows.append(("fleet_day_cycle_wall_s", cyc * 1e6 / 1e6,
                 f"{cfg.n_clusters} clusters, full pipeline"))
    rows += fig7_forecast_ape(st, recs)
    rows += fig3_load_shaping(st, recs)
    rows += fig9_11_cluster_regimes(st, recs)
    rows += fig12_controlled_experiment()
    rows += power_model_mape()
    rows += carbon_forecast_mape()
    return rows
