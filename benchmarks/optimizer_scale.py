"""Optimizer scalability (paper §III-C claims fleetwide scalability) +
kernel microbenchmarks (flash attention, GLA, fused PGD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core.vcc import VCCProblem, solve_vcc


def _problem(n, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    H = 24
    eta = jnp.abs(0.3 + 0.2 * jnp.sin(jnp.linspace(0, 2 * jnp.pi, H))[None]
                  + 0.05 * jax.random.normal(ks[0], (n, H)))
    u_if = 0.4 + 0.05 * jax.random.normal(ks[1], (n, H))
    return VCCProblem(
        eta=eta, u_if=u_if, u_if_q=u_if * 1.1,
        tau=2.0 + 3.0 * jax.random.uniform(ks[2], (n,)),
        pow_nom=500.0 + 20.0 * jax.random.normal(ks[3], (n, H)),
        pi=jnp.full((n, H), 300.0),
        u_pow_cap=jnp.full((n,), 0.95), capacity=jnp.full((n,), 1.3),
        ratio=jnp.full((n, H), 1.3),
        campus=jnp.asarray(np.arange(n) % max(n // 8, 1), jnp.int32),
        campus_limit=jnp.full((max(n // 8, 1),), 1e9),
        lambda_e=0.1, lambda_p=0.05)


def run():
    rows = []
    for n in (256, 2048, 16384):
        p = _problem(n)
        fn = jax.jit(lambda pp=p: solve_vcc(pp, inner_iters=60,
                                            outer_iters=5).delta)
        us = timeit(fn, warmup=1, iters=3)
        rows.append((f"vcc_solve_n{n}", us,
                     f"{us / n:.2f} us/cluster/day (fleetwide daily run)"))
    # kernel micro: flash attention vs bounded-memory XLA path
    from repro.kernels.flash_attention.ref import (attention_chunked,
                                                   attention_reference)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, N, K, H = 2, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, N, H), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, H), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, H), jnp.float32)
    f_ref = jax.jit(lambda q, k, v: attention_reference(q, k, v))
    f_chn = jax.jit(lambda q, k, v: attention_chunked(q, k, v, q_chunk=256))
    rows.append(("attn_exact_1k", timeit(f_ref, q, k, v),
                 "O(S^2) memory oracle"))
    rows.append(("attn_chunked_1k", timeit(f_chn, q, k, v),
                 "bounded-memory XLA path (prod)"))
    # GLA chunked vs naive
    from repro.kernels.linear_scan.ref import gla_chunked, gla_naive
    q2 = jax.random.normal(ks[0], (2, 512, 4, 64))
    k2 = jax.random.normal(ks[1], (2, 512, 4, 64))
    v2 = jax.random.normal(ks[2], (2, 512, 4, 64))
    ld = -jnp.abs(jax.random.normal(ks[0], (2, 512, 4))) * 0.5
    g_naive = jax.jit(lambda: gla_naive(q2, k2, v2, ld)[0])
    g_chunk = jax.jit(lambda: gla_chunked(q2, k2, v2, ld, chunk=64)[0])
    rows.append(("gla_naive_512", timeit(g_naive),
                 "sequential recurrence"))
    rows.append(("gla_chunked_512", timeit(g_chunk),
                 "chunked (TPU-shaped) algorithm"))
    # fused PGD epoch (jnp ref; the Pallas kernel is the TPU fast path)
    from repro.kernels.vcc_pgd.ref import pgd_epoch_ref
    n, Hh = 4096, 24
    kk = jax.random.split(jax.random.PRNGKey(2), 6)
    args = (jnp.zeros((n, Hh)),
            0.2 + 0.2 * jax.random.uniform(kk[0], (n, Hh)),
            200 + 100 * jax.random.uniform(kk[1], (n, Hh)),
            400 + 100 * jax.random.uniform(kk[2], (n, Hh)),
            0.05 + 0.2 * jax.random.uniform(kk[3], (n, 1)),
            0.05 * jnp.ones((n, 1)),
            jnp.full((n, Hh), -0.8),
            0.5 + jax.random.uniform(kk[4], (n, Hh)),
            0.01 * jnp.ones((n, 1)))
    f_pgd = jax.jit(lambda *a: pgd_epoch_ref(*a, temp=10.0, lambda_e=0.3,
                                             iters=60))
    rows.append(("vcc_pgd_epoch_n4096", timeit(f_pgd, *args),
                 "60 PGD iters, fused (Pallas kernel mirrors this)"))
    return rows
