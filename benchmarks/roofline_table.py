"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS.md
§Roofline reads from this)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_records(mesh: str = "pod"):
    recs = []
    for p in sorted(RESULTS.glob(f"*_{mesh}.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def markdown_table(mesh: str = "pod") -> str:
    rows = ["| arch | shape | dominant | compute_s | memory_s | coll_s | "
            "roofline_frac | useful_flops | fits16G |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh):
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - "
                        f"| - | - | - |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - "
                        f"| - | - | - |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['dominant']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['roofline_fraction']:.3f} "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {r['memory']['fits_16g']} |")
    return "\n".join(rows)


def run():
    rows = []
    for mesh in ("pod", "multipod"):
        recs = [r for r in load_records(mesh) if "roofline" in r]
        if not recs:
            continue
        ok = len(recs)
        fits = sum(1 for r in recs if r["memory"]["fits_16g"])
        frac = sum(r["roofline"]["roofline_fraction"] for r in recs) / ok
        rows.append((f"dryrun_{mesh}_cells_ok", float(ok),
                     f"fits16G={fits}/{ok} mean_roofline_frac={frac:.3f}"))
    return rows


if __name__ == "__main__":
    print(markdown_table("pod"))
