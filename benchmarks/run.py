# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (us_per_call doubles as the metric value for non-timing rows).
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (fleet_bench, optimizer_scale, roofline_table,
                            sim_bench)
    print("name,us_per_call,derived")
    all_rows = []
    for mod in (fleet_bench, optimizer_scale, roofline_table, sim_bench):
        try:
            all_rows += mod.run()
        except Exception as e:  # noqa: BLE001
            all_rows.append((f"{mod.__name__}_FAILED", -1.0,
                             f"{type(e).__name__}: {e}"))
    for name, val, derived in all_rows:
        d = str(derived).replace(",", ";")
        print(f"{name},{float(val):.4f},{d}")
    print(f"total_wall_s,{time.time() - t0:.1f},benchmark harness runtime")


if __name__ == '__main__':
    main()
