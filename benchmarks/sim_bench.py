"""Scenario-sweep benchmark: batched engine vs legacy Python day loop.

Emits BENCH_sim.json (repo root) with rollout throughput in fleet-days/sec
for the vmap-batched engine and the legacy per-day Python loop in
core/fleet.py, plus the per-scenario summary rows. Registered in run.py.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro.core import fleet as F
from repro.sim import (SimConfig, build_batch, default_library,
                       rollout_batch, scenario_rows)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_sim.json"


def _legacy_days_per_sec(n_clusters=8, days=3, seed=1):
    """Legacy path: mutable FleetState stepped by a Python day loop."""
    cfg = F.FleetConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                        lambda_e=0.5, seed=seed)
    st = F.init_fleet(cfg)
    st = F.day_cycle(st)               # warm-up day: amortize jit tracing
    jax.block_until_ready(st.queue)
    t0 = time.perf_counter()
    for _ in range(days):
        st = F.day_cycle(st)
    jax.block_until_ready(st.queue)
    wall = time.perf_counter() - t0
    return days / wall, wall


def _batched_days_per_sec(n_clusters=8, days=7, n_scen=4, n_seeds=2,
                          hist_days=28):
    cfg = SimConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                    pds_per_cluster=2, hist_days=hist_days)
    scens = default_library(days)[:n_scen]
    seeds = list(range(n_seeds))
    batch = build_batch(cfg, scens, seeds, days)
    run = rollout_batch(cfg, days)
    t0 = time.perf_counter()
    _, led, _ = run(batch)
    jax.block_until_ready(led)
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, led, _ = run(batch)
    jax.block_until_ready(led)
    wall = time.perf_counter() - t0
    fleet_days = n_scen * n_seeds * days
    rows = scenario_rows(led, [s.name for s in scens], n_seeds)
    return fleet_days / wall, wall, compile_wall, fleet_days, rows


def run():
    base_dps, base_wall = _legacy_days_per_sec()
    (bat_dps, bat_wall, compile_wall, fleet_days,
     rows) = _batched_days_per_sec()
    speedup = bat_dps / base_dps
    rec = {
        "legacy_python_loop_days_per_sec": base_dps,
        "batched_engine_days_per_sec": bat_dps,
        "speedup_days_per_sec": speedup,
        "batched_fleet_days": fleet_days,
        "batched_steady_wall_s": bat_wall,
        "batched_compile_wall_s": compile_wall,
        "legacy_wall_s": base_wall,
        "scenarios": rows,
    }
    BENCH_PATH.write_text(json.dumps(rec, indent=1))
    out = [
        ("sim_legacy_days_per_sec", base_dps, "Python day loop, 8 clusters"),
        ("sim_batched_days_per_sec", bat_dps,
         f"{fleet_days} fleet-days vmap'd, steady state"),
        ("sim_batched_speedup", speedup, "target: >= 5x"),
    ]
    for r in rows:
        out.append((f"sim_{r['scenario']}_carbon_saved_pct",
                    r["carbon_saved_pct"],
                    f"peakRed={r['peak_reduction_pct']:.2f}% "
                    f"flex24h={r['flex_within_24h_pct']:.2f}%"))
    return out
