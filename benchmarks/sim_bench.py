"""Scenario-sweep benchmark: batched engine vs legacy Python day loop.

Emits BENCH_sim.json (repo root) with rollout throughput in fleet-days/sec
for the vmap-batched engine, the device-sharded batched engine
(`rollout_batch_sharded`), and the legacy per-day Python loop in
core/fleet.py, plus a legacy-vs-engine drift probe (both paths run the
same staged day step, so drift must be ~0), the per-scenario summary
rows, the K=8 CVaR ensemble solve cost relative to the K=1 point-forecast
solve (the member axis is vmapped/kernel-reduced, so the target is << Kx),
the risk-sweep (beta) trade-off rows, the joint spatio-temporal solve
cost relative to the temporal-only solve plus its carbon edge over the
sequential pre-shift (`joint_solve_cost_ratio` / `joint_carbon_delta_pct`),
the mobility-sweep rows (joint vs sequential rollouts of the same
batch), the horizon-scaling rows (streaming vs rescan days/s at
H in {56, 182, 364} with per-rollout state bytes), and the 14-day
streaming-vs-rescan forecast-drift probe. Registered in run.py; also a
CLI:

    PYTHONPATH=src python -m benchmarks.sim_bench [--quick] [--out PATH]

``--quick`` runs a small CI smoke configuration and FAILS (exit 1) if the
batched engine loses its throughput edge over the legacy loop, if the
legacy and engine paths drift apart, if the K=8 ensemble solve costs
>= 4x the K=1 solve, if the per-member ensemble throughput regresses
>1.5x against the committed BENCH_sim.json baseline, if the joint
spatio-temporal solve costs >= 3x the temporal-only solve, if the
joint optimizer's carbon is worse than the sequential pre-shift
(solver-level: exact gate, the best-of safeguard makes plan-level
dominance structural; rollout-level: a generous tripwire per
mobility-sweep row, since REALIZED carbon after sampled load can wiggle
either way), if the streaming day step is no longer O(1) in history
length (days/s at H=364 must stay within 1.3x of H=56), if the
streaming forecasts drift >= 0.35 from the rescan pipeline over a
14-day dual run, if PredictorState stops being strictly smaller than
the seven replaced hist_* windows at H=364, if the telemetry-off day
step stops compiling to the byte-identical legacy HLO (the collapse
contract), or if the telemetry-on rollout costs >= 15% over the
telemetry-off rollout — the regression tripwires the CI workflow runs
on every push. Every failed gate prints the measured value against the
gate threshold. Quick mode also exports the telemetry JSONL trace
(TELEMETRY_trace.jsonl next to the --out json) and per-stage cost rows
(``stage_costs`` in the json) — the CI artifacts.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, fleet as F
from repro.core import risk, solver, spatial, stats, vcc
from repro.core import stages as stages_mod
from repro.core.stages import hour_sum
from repro.sim import (SimConfig, Scenario, build_batch, build_params,
                       default_library, forecast_bust_library,
                       make_day_step, make_init, make_rollout,
                       mobility_sweep_library, mobility_sweep_rows,
                       mpc_recourse_rows, risk_sweep_library,
                       risk_sweep_rows, rollout_batch,
                       rollout_batch_sharded, scenario_rows, state_nbytes,
                       telemetry_records, write_jsonl)
from repro.sim import telemetry as telemetry_mod
from repro.sim.engine import _day_xs

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_sim.json"


def _legacy_days_per_sec(n_clusters=8, days=3, seed=1, hist_days=None):
    """Legacy path: mutable FleetState stepped by a Python day loop (now
    one jitted staged step per day — the old eager loop is gone)."""
    kw = {} if hist_days is None else {"hist_days": hist_days}
    cfg = F.FleetConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                        lambda_e=0.5, seed=seed, **kw)
    st = F.init_fleet(cfg)
    st = F.day_cycle(st)               # warm-up day: amortize jit tracing
    jax.block_until_ready(st.queue)
    t0 = time.perf_counter()
    for _ in range(days):
        st = F.day_cycle(st)
    jax.block_until_ready(st.queue)
    wall = time.perf_counter() - t0
    return days / wall, wall


def _batched_days_per_sec(n_clusters=8, days=7, n_scen=4, n_seeds=2,
                          hist_days=28, sharded=False):
    cfg = SimConfig(n_clusters=n_clusters, n_campuses=4, n_zones=4,
                    pds_per_cluster=2, hist_days=hist_days)
    scens = default_library(days)[:n_scen]
    seeds = list(range(n_seeds))
    batch = build_batch(cfg, scens, seeds, days)
    run = (rollout_batch_sharded if sharded else rollout_batch)(cfg, days)
    t0 = time.perf_counter()
    state, led, _ = run(batch)
    jax.block_until_ready(led)
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    state, led, _ = run(batch)
    jax.block_until_ready(led)
    wall = time.perf_counter() - t0
    fleet_days = n_scen * n_seeds * days
    rows = scenario_rows(led, [s.name for s in scens], n_seeds,
                         horizon_days=days,
                         state_bytes=state_nbytes(state,
                                                  batch=n_scen * n_seeds))
    return fleet_days / wall, wall, compile_wall, fleet_days, rows


def _horizon_scaling(n_clusters=4, days=6, reps=3, horizons=(56, 182, 364)):
    """Steady-state DAY-STEP throughput vs history length, streaming vs
    rescan, one rollout per config. Burn-in (init) runs once and is
    excluded — it is one-time O(H) cost in both modes; what must not
    scale with H is the carried day cycle. The rescan path's day-step
    cost and state grow with H (seven (n, H, 24) windows rolled daily +
    O(H) EWMA scans); the streaming path must be ~flat: days/s at H=364
    within 1.3x of H=56 (CI gate), and its PredictorState strictly
    smaller than the seven replaced hist_* windows at H=364 (CI gate)."""
    rows = []
    sc = Scenario("horizon_probe", "nominal fleet, horizon-scaling probe")
    for streaming in (False, True):
        for H in horizons:
            cfg = SimConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                            pds_per_cluster=2, hist_days=H,
                            streaming=streaming)
            batch = build_batch(cfg, [sc], [0], days)
            init = jax.jit(jax.vmap(make_init(cfg)))
            roll = jax.jit(jax.vmap(make_rollout(cfg, days)))
            state0 = init(batch)
            jax.block_until_ready(state0)
            _, led, _ = roll(batch, state0)          # compile the scan
            jax.block_until_ready(led)
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                _, led, _ = roll(batch, state0)
                jax.block_until_ready(led)
                best = min(best, time.perf_counter() - t0)
            row = {
                "mode": "streaming" if streaming else "rescan",
                "horizon_days": H,
                "days_per_sec": days / best,
                "state_bytes": state_nbytes(state0, batch=1),
            }
            if streaming:
                row["predictor_bytes"] = stats.predictor_nbytes(state0.pred)
            else:
                row["replaced_hist_bytes"] = \
                    stats.replaced_hist_nbytes(state0)
            rows.append(row)
    return rows


def _streaming_drift(n_clusters=4, hist_days=28, days=14, seed=0):
    """Max per-day relative drift (max |stream - rescan| / mean |rescan|
    over uif/tuf/tr) of the streaming forecasts against the rescan
    pipeline over a dual run replaying the SAME realized telemetry.
    Day 0 is exact (handoff-bitwise warm start); after that the two
    paths are different-memory estimators of the same quantities, and
    this gate pins their divergence (documented tolerance: < 0.35, see
    tests/test_streaming.py)."""
    cfg = SimConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                    pds_per_cluster=2, hist_days=hist_days)
    sc = Scenario("stream_drift_probe", lambda_e=0.5)
    p = build_params(cfg, sc, seed=seed, days=days)
    s = jax.jit(make_init(cfg))(p)
    pred = stats.init_predictor(
        s.hist_uif, s.hist_flex_daily, s.hist_res_daily, s.hist_usage,
        s.hist_res, s.hist_tr_pred, s.hist_uif_pred, s.day, p.gamma)
    step = jax.jit(make_day_step(cfg))
    worst = 0.0
    for d in range(days):
        fc_s = stats.streaming_forecast(pred, s.day, p.gamma)
        s2, out = step(p, s, _day_xs(p, d))
        for k in ("uif", "tuf", "tr"):
            a, b = np.asarray(out.fc[k]), np.asarray(fc_s[k])
            worst = max(worst, float(np.max(np.abs(a - b))
                                     / (np.mean(np.abs(a)) + 1e-9)))
        pred = stats.predictor_update(
            pred, fc_s, s.day, p.gamma, s2.hist_uif[:, -1], out.res.served,
            hour_sum(out.res.reservations), out.res.usage_total,
            out.res.reservations)
        s = s2
    return worst


def _legacy_engine_drift(n_clusters=4, hist_days=14, seed=0):
    """Max relative drift between one legacy ``fleet.day_cycle`` day and
    the engine's ``day_step`` from the same burned-in state. Both are
    adapters over the same staged core, so this must be ~0 (bitwise on a
    deterministic backend); growth here means the two paths forked."""
    fcfg = F.FleetConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                         pds_per_cluster=2, lambda_e=0.5, lambda_p=0.05,
                         gamma=0.05, seed=seed, hist_days=hist_days)
    scfg = SimConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                     pds_per_cluster=2, hist_days=hist_days)
    sc = Scenario("drift_probe", lambda_e=0.5, lambda_p=0.05, gamma=0.05)
    p = build_params(scfg, sc, seed=seed, days=1)
    s = jax.jit(make_init(scfg))(p)
    s2, out = jax.jit(make_day_step(scfg))(p, s, _day_xs(p, 0))
    st = F.init_fleet(fcfg)
    rec = {}
    st = F.day_cycle(st, rec)
    drift = 0.0
    for a, b in ((rec["vcc"], out.vcc_curve),
                 (st.queue, s2.queue),
                 (st.hist_usage, s2.hist_usage),
                 (rec["result"].carbon, out.res.carbon)):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        denom = np.maximum(np.abs(a), 1e-9)
        drift = max(drift, float(np.max(np.abs(a - b) / denom)))
    return drift


def _ensemble_solve_cost(n_clusters=256, n_members=8, reps=5):
    """Wall-time of the K-member CVaR solve vs the K=1 point-forecast
    solve (jitted; min over ``reps`` steady-state calls — the standard
    low-variance estimator, this ratio is CI-gated). The ensemble epoch
    reduces the member axis in-kernel and the bisection projection is
    member-independent, so the target is << Kx (acceptance: < 4x at
    K=8). The problem is vcc.synthetic_problem — the SAME recipe the
    parity tests solve."""
    p = vcc.synthetic_problem(n_clusters, seed=11, n_campuses=4)
    prof = 1.0 + 0.3 * jax.random.normal(jax.random.PRNGKey(0),
                                         (n_members, 1, 24))
    eta_ens = jnp.clip(jnp.broadcast_to(p.eta[None], (n_members,)
                                        + p.eta.shape)
                       * prof.at[0].set(1.0), 1e-4, None)
    uif_ens = jnp.broadcast_to(p.u_if[None], (n_members,) + p.u_if.shape)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.5)

    def timed(prob):
        f = jax.jit(lambda q: vcc.solve_vcc(q, use_pallas=False).delta)
        jax.block_until_ready(f(prob))           # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f(prob))
            best = min(best, time.perf_counter() - t0)
        return best

    k1_s = timed(p)
    k8_s = timed(pe)
    return {
        "ensemble_k1_solve_ms": 1e3 * k1_s,
        "ensemble_k8_solve_ms": 1e3 * k8_s,
        "ensemble_n_members": n_members,
        "ensemble_solve_cost_ratio": k8_s / k1_s,
        # member-cluster-solves per second: the per-member throughput the
        # quick gate compares against the committed baseline
        "ensemble_per_member_clusters_per_sec":
            n_members * n_clusters / k8_s,
    }


def _joint_solve_cost(n_clusters=256, mobility=0.3, reps=5):
    """Wall-time of the joint spatio-temporal solve vs the temporal-only
    solve (jitted; min over ``reps`` steady-state calls), plus the
    model-consistent carbon edge over the sequential pre-shift. The joint
    solve CONTAINS a sequential warm start + the joint refinement, so the
    ratio's floor is ~1; the CI gate caps it at 3x. Carbon delta >= 0 is
    structural (best-of safeguard in ``spatial.solve_joint``). The
    problem is ``vcc.synthetic_zonal_problem`` — the SAME zonal recipe
    the joint tests solve (one recipe, no drift)."""
    p = vcc.synthetic_zonal_problem(n_clusters, seed=13, n_campuses=4)

    f_t = jax.jit(lambda q: vcc.solve_vcc(q, use_pallas=False).delta)
    f_j = jax.jit(lambda q: spatial.solve_joint(q, mobility,
                                                use_pallas=False))

    def timed(f, arg):
        jax.block_until_ready(f(arg))            # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f(arg))
            best = min(best, time.perf_counter() - t0)
        return best

    t_temporal = timed(f_t, p)
    t_joint = timed(f_j, p)
    sol_j, _, s_j = f_j(p)
    # sequential two-phase baseline, evaluated on the SAME joint-consistent
    # carbon model (incl. the pi*s/24 baseline term it ignores)
    tau_sh, _ = spatial.spatial_shift(p, mobility=mobility)
    sol_seq = vcc.solve_vcc(dataclasses.replace(p, tau=tau_sh),
                            use_pallas=False)
    s0 = tau_sh - p.tau
    c_joint = float(spatial.joint_carbon(p, sol_j.delta, s_j))
    c_seq = float(spatial.joint_carbon(p, sol_seq.delta, s0))
    return {
        "joint_temporal_solve_ms": 1e3 * t_temporal,
        "joint_solve_ms": 1e3 * t_joint,
        "joint_solve_cost_ratio": t_joint / t_temporal,
        "joint_carbon_kg": c_joint,
        "joint_sequential_carbon_kg": c_seq,
        # > 0 = joint emits less than the sequential pre-shift
        "joint_carbon_delta_pct": 100.0 * (c_seq - c_joint)
        / max(abs(c_seq), 1e-9),
    }


def _mobility_sweep_rows(n_clusters=6, days=7, n_seeds=2, hist_days=14,
                         mobilities=None):
    """The mobility-sweep family through the engine, twice over the same
    (scenario x seed) batch: joint_spatial=True vs False. Rows carry the
    rollout-level joint-vs-sequential carbon delta
    (``carbon_vs_sequential_pct``; the quick gate tripwires only on
    substantial negatives — realized carbon is noisy, plan-level
    dominance is gated exactly at the solver probe)."""
    kw = {} if mobilities is None else {"mobilities": mobilities}
    scens = mobility_sweep_library(days, **kw)
    seeds = list(range(n_seeds))
    ledgers = {}
    for joint in (True, False):
        cfg = SimConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                        pds_per_cluster=2, hist_days=hist_days,
                        joint_spatial=joint)
        batch = build_batch(cfg, scens, seeds, days)
        _, led, _ = rollout_batch(cfg, days)(batch)
        jax.block_until_ready(led)
        ledgers[joint] = led
    return mobility_sweep_rows(ledgers[True], ledgers[False],
                               [s.name for s in scens], n_seeds)


def _risk_sweep_rows(n_clusters=6, days=4, members=(1, 8), n_seeds=2,
                     hist_days=14):
    """The risk-sweep family (beta axis batched, K static: one compiled
    batch per ensemble size) through the engine. K=1 is the degenerate
    control — its beta rows must be identical — and K>1 shows the carbon
    vs flex-completion trade-off across beta. Row flattening is
    report.risk_sweep_rows — the same helper the example table uses."""
    scens = risk_sweep_library(days)
    seeds = list(range(n_seeds))
    ledgers_by_k = {}
    for n_members in members:
        cfg = SimConfig(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                        pds_per_cluster=2, hist_days=hist_days,
                        n_members=n_members)
        batch = build_batch(cfg, scens, seeds, days)
        _, led, _ = rollout_batch(cfg, days)(batch)
        jax.block_until_ready(led)
        ledgers_by_k[n_members] = led
    return risk_sweep_rows(ledgers_by_k, [s.name for s in scens], n_seeds)


def _legacy_dual_ascent(inner, dual_update, x0, mu0, outer_iters):
    """Verbatim pre-telemetry ``solver.dual_ascent`` (the two-value scan).
    The collapse probe traces the day step against THIS to certify that
    ``telemetry=False`` still compiles to the byte-identical legacy HLO."""
    def outer(carry, _):
        x, mu = carry
        x = inner(x, mu)
        mu = dual_update(x, mu)
        return (x, mu), None

    (x, mu), _ = jax.lax.scan(outer, (x0, mu0), None, length=outer_iters)
    return x, mu


def _telemetry_probe(n_clusters=6, days=4, n_scen=2, n_seeds=2,
                     hist_days=14, reps=3):
    """Telemetry collapse + overhead + stage-cost attribution probe.

    Times the SAME (scenario x seed) batch rollout with telemetry off and
    on (steady state, best-of-``reps``) -> ``telemetry_overhead_pct``
    (CI gate: < 15%); byte-compares the telemetry-off day-step HLO
    against the graph traced with the pre-telemetry dual-ascent scan ->
    ``telemetry_hlo_identical`` (CI gate: must hold); profiles per-stage
    compiled cost (``sim.telemetry.profile_stages``) -> ``stage_costs``
    rows; and returns the exported JSONL trace records."""
    base = dict(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                pds_per_cluster=2, hist_days=hist_days)
    cfg_off = SimConfig(**base)
    cfg_on = SimConfig(**base, telemetry=True)
    scens = default_library(days)[:n_scen]
    seeds = list(range(n_seeds))
    batch = build_batch(cfg_off, scens, seeds, days)

    def timed(cfg):
        run_fn = rollout_batch(cfg, days)
        out = run_fn(batch)
        jax.block_until_ready(out)               # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = run_fn(batch)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_off, _ = timed(cfg_off)
    t_on, (_, _, traj) = timed(cfg_on)

    # collapse contract: telemetry-off day step == pre-telemetry graph
    p1 = build_params(cfg_off, scens[0], 0, days)
    s1 = jax.jit(make_init(cfg_off))(p1)
    xs = _day_xs(p1, 0)
    scfg = cfg_off.stage_config()
    hlo_off = stages_mod.jitted_day_step(scfg).lower(p1, s1, xs).as_text()
    orig = solver.dual_ascent
    solver.dual_ascent = _legacy_dual_ascent
    stages_mod.jitted_day_step.cache_clear()
    try:
        hlo_legacy = stages_mod.jitted_day_step(scfg).lower(
            p1, s1, xs).as_text()
    finally:
        solver.dual_ascent = orig
        stages_mod.jitted_day_step.cache_clear()

    stage_costs = telemetry_mod.profile_stages(scfg, p1, s1, reps=reps)
    records = telemetry_records(traj["telemetry"],
                                [s.name for s in scens], n_seeds)
    return {
        "telemetry_rollout_off_s": t_off,
        "telemetry_rollout_on_s": t_on,
        "telemetry_overhead_pct": 100.0 * (t_on / t_off - 1.0),
        "telemetry_hlo_identical": bool(hlo_off == hlo_legacy),
        "stage_costs": stage_costs,
    }, records


def _legacy_run_day(vcc, u_if, arrivals, ratio, capacity, queue0, power_fn,
                    intensity, allowance_frac: float = 0.25):
    """Verbatim pre-MPC ``admission.run_day`` (inline tick + hard-coded
    0.25 late-day allowance; ``allowance_frac`` accepted for call
    compatibility, unused — the default-config trace passes 0.25). The
    collapse probe traces the day step against THIS to certify that
    ``mpc=False`` still compiles to the byte-identical open-loop HLO."""
    def tick(queue, inp):
        vcc_h, uif_h, arr_h, r_h = inp
        flex_room_res = jnp.clip(vcc_h - uif_h * r_h, 0.0, None)
        flex_room = flex_room_res / jnp.clip(r_h, 1.0, None)
        flex_room = jnp.minimum(flex_room,
                                jnp.clip(capacity - uif_h, 0.0, None))
        demand = queue + arr_h
        use_flex = jnp.minimum(demand, flex_room)
        queue = demand - use_flex
        return queue, (use_flex, queue)

    xs = (vcc.T, u_if.T, arrivals.T, ratio.T)
    queue_end, (use_flex, queue_traj) = jax.lax.scan(tick, queue0, xs)
    use_flex = use_flex.T                       # (n, 24)
    usage_total = u_if + use_flex
    reservations = usage_total * ratio
    power = jax.vmap(power_fn, in_axes=1, out_axes=1)(usage_total)
    carbon = power * intensity
    arrived = hour_sum(arrivals)
    served = hour_sum(use_flex)
    allowance = 0.25 * arrived
    unmet = jnp.clip(queue_end - queue0 - allowance, 0.0, None)
    return admission.DayResult(
        usage_flex=use_flex, usage_total=usage_total,
        reservations=reservations, power=power, carbon=carbon,
        served=served, arrived=arrived, queue_end=queue_end, unmet=unmet)


def _mpc_probe(n_clusters=6, days=4, n_seeds=2, hist_days=14, reps=3,
               solve_clusters=256):
    """Intra-day MPC recourse probe: three CI-gated measures.

    1. Hourly re-solve cost: the warm-started suffix solve
       (``vcc.solve_vcc_suffix``, 2x8 PGD steps over the remaining hours)
       vs the full day-ahead solve (20x80) on the same synthetic fleet —
       gate: ratio < 1/24, so 24 hourly re-solves stay cheaper than one
       extra day solve.
    2. Closed-vs-open loop outcomes: the forecast-busting library
       (randomly placed intra-day carbon/arrival blocks the planner never
       saw) rolled out twice over the SAME batch, mpc=True vs mpc=False —
       gate: every row improves carbon OR within-24h flex service.
    3. Collapse contract: the mpc=False day-step HLO byte-compared
       against the graph traced with the verbatim pre-MPC
       ``admission.run_day`` — gate: identical (same contract as the
       telemetry flag)."""
    # --- 1. suffix re-solve vs full solve wall time
    p = vcc.synthetic_problem(solve_clusters, seed=11, n_campuses=4)
    f_full = jax.jit(lambda q: vcc.solve_vcc(q, use_pallas=False).delta)
    sol0 = vcc.solve_vcc(p, use_pallas=False)
    f_sfx = jax.jit(lambda q, d0, m0: vcc.solve_vcc_suffix(
        q, d0, m0, 8, use_pallas=False).delta)

    def timed(f, *args):
        jax.block_until_ready(f(*args))          # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    t_full = timed(f_full, p)
    t_sfx = timed(f_sfx, p, sol0.delta, sol0.mu)

    # --- 2. closed vs open loop on the forecast-busting scenarios
    base = dict(n_clusters=n_clusters, n_campuses=2, n_zones=2,
                pds_per_cluster=2, hist_days=hist_days)
    cfg_open = SimConfig(**base)
    cfg_mpc = SimConfig(**base, mpc=True)
    scens = forecast_bust_library(days)
    seeds = list(range(n_seeds))
    batch = build_batch(cfg_open, scens, seeds, days)
    _, led_open, _ = rollout_batch(cfg_open, days)(batch)
    _, led_mpc, _ = rollout_batch(cfg_mpc, days)(batch)
    jax.block_until_ready((led_open, led_mpc))
    rows = mpc_recourse_rows(led_mpc, led_open, [s.name for s in scens],
                             n_seeds)

    # --- 3. collapse contract: mpc=False HLO == pre-MPC open-loop graph
    p1 = build_params(cfg_open, default_library(days)[0], 0, days)
    s1 = jax.jit(make_init(cfg_open))(p1)
    xs = _day_xs(p1, 0)
    scfg = cfg_open.stage_config()
    hlo_off = stages_mod.jitted_day_step(scfg).lower(p1, s1, xs).as_text()
    orig = admission.run_day
    admission.run_day = _legacy_run_day
    stages_mod.jitted_day_step.cache_clear()
    try:
        hlo_legacy = stages_mod.jitted_day_step(scfg).lower(
            p1, s1, xs).as_text()
    finally:
        admission.run_day = orig
        stages_mod.jitted_day_step.cache_clear()

    return {
        "mpc_full_solve_ms": 1e3 * t_full,
        "mpc_suffix_solve_ms": 1e3 * t_sfx,
        "mpc_resolve_cost_ratio": t_sfx / t_full,
        "mpc_rows": rows,
        "mpc_carbon_delta_pct": float(np.mean(
            [r["carbon_vs_open_pct"] for r in rows])),
        "mpc_flex24h_delta_pp": float(np.mean(
            [r["flex24h_vs_open_pp"] for r in rows])),
        "mpc_hlo_identical": bool(hlo_off == hlo_legacy),
    }


def run(quick: bool = False, out_path: Path = None):
    # quick mode must never clobber the committed full-run baseline it is
    # gated against; default its output to a sibling file
    if quick and out_path is None:
        out_path = BENCH_PATH.with_name("BENCH_sim_quick.json")
    if quick:
        legacy_kw = dict(n_clusters=4, days=2, hist_days=14)
        batch_kw = dict(n_clusters=4, days=4, n_scen=3, n_seeds=2,
                        hist_days=14)
        # same problem size and reps as the full run: the cost-ratio gate
        # compares against the committed BENCH_sim.json baseline
        ens_kw = dict()
        joint_kw = dict()
        risk_kw = dict(n_clusters=4, days=3, members=(8,), n_seeds=1)
        mob_kw = dict(n_clusters=4, days=3, n_seeds=1,
                      mobilities=(0.0, 0.3))
        # horizon-scaling + drift probes run the SAME H set as the full
        # run: the acceptance gates are defined at H in {56, 182, 364}
        hor_kw = dict(days=4, reps=2)
        stream_kw = dict()
        tel_kw = dict(n_clusters=4, days=3, reps=2)
        mpc_kw = dict(n_clusters=4, days=4, n_seeds=2, reps=2)
    else:
        legacy_kw, batch_kw, ens_kw, risk_kw = {}, {}, {}, {}
        joint_kw, mob_kw, hor_kw, stream_kw, tel_kw = {}, {}, {}, {}, {}
        mpc_kw = {}
    base_dps, base_wall = _legacy_days_per_sec(**legacy_kw)
    (bat_dps, bat_wall, compile_wall, fleet_days,
     rows) = _batched_days_per_sec(**batch_kw)
    (shard_dps, shard_wall, shard_compile, _,
     _) = _batched_days_per_sec(sharded=True, **batch_kw)
    drift = _legacy_engine_drift()
    ens = _ensemble_solve_cost(**ens_kw)
    joint = _joint_solve_cost(**joint_kw)
    risk_rows = _risk_sweep_rows(**risk_kw)
    mob_rows = _mobility_sweep_rows(**mob_kw)
    hor_rows = _horizon_scaling(**hor_kw)
    stream_drift = _streaming_drift(**stream_kw)
    tel, trace_records = _telemetry_probe(**tel_kw)
    mpc = _mpc_probe(**mpc_kw)
    by_mode_h = {(r["mode"], r["horizon_days"]): r for r in hor_rows}
    h_lo, h_hi = min(r["horizon_days"] for r in hor_rows), \
        max(r["horizon_days"] for r in hor_rows)
    stream_slowdown = by_mode_h[("streaming", h_lo)]["days_per_sec"] \
        / by_mode_h[("streaming", h_hi)]["days_per_sec"]
    speedup = bat_dps / base_dps
    rec = {
        "legacy_python_loop_days_per_sec": base_dps,
        "batched_engine_days_per_sec": bat_dps,
        "sharded_engine_days_per_sec": shard_dps,
        "n_devices": len(jax.devices()),
        "speedup_days_per_sec": speedup,
        "legacy_engine_drift_relmax": drift,
        "batched_fleet_days": fleet_days,
        "batched_steady_wall_s": bat_wall,
        "batched_compile_wall_s": compile_wall,
        "sharded_steady_wall_s": shard_wall,
        "sharded_compile_wall_s": shard_compile,
        "legacy_wall_s": base_wall,
        "quick": quick,
        "scenarios": rows,
        "risk_sweep": risk_rows,
        "mobility_sweep": mob_rows,
        "horizon_scaling": hor_rows,
        "streaming_forecast_drift": stream_drift,
        "stream_slowdown_h364_vs_h56": stream_slowdown,
        "predictor_bytes_h364":
            by_mode_h[("streaming", h_hi)]["predictor_bytes"],
        "replaced_hist_bytes_h364":
            by_mode_h[("rescan", h_hi)]["replaced_hist_bytes"],
        **ens,
        **joint,
        **tel,
        **mpc,
    }
    dest = out_path or BENCH_PATH
    dest.write_text(json.dumps(rec, indent=1))
    # the structured trace the CI workflow uploads as an artifact
    write_jsonl(dest.with_name("TELEMETRY_trace.jsonl"), trace_records)
    out = [
        ("sim_legacy_days_per_sec", base_dps,
         "Python day loop over the jitted staged step"),
        ("sim_batched_days_per_sec", bat_dps,
         f"{fleet_days} fleet-days vmap'd, steady state"),
        ("sim_sharded_days_per_sec", shard_dps,
         f"shard_map over {len(jax.devices())} device(s)"),
        ("sim_batched_speedup", speedup, "target: >= 5x"),
        ("sim_legacy_engine_drift", drift, "same staged core: ~0 required"),
        ("sim_ensemble_solve_cost_ratio", ens["ensemble_solve_cost_ratio"],
         f"K={ens['ensemble_n_members']} CVaR solve vs K=1 "
         f"({ens['ensemble_k8_solve_ms']:.1f}ms vs "
         f"{ens['ensemble_k1_solve_ms']:.1f}ms); target < 4x"),
        ("sim_ensemble_per_member_clusters_per_sec",
         ens["ensemble_per_member_clusters_per_sec"],
         "member-cluster solves/sec (informational; the quick gate "
         "compares the machine-normalized cost ratio vs BENCH_sim.json)"),
        ("sim_joint_solve_cost_ratio", joint["joint_solve_cost_ratio"],
         f"joint spatio-temporal solve vs temporal-only "
         f"({joint['joint_solve_ms']:.1f}ms vs "
         f"{joint['joint_temporal_solve_ms']:.1f}ms); target < 3x"),
        ("sim_joint_carbon_delta_pct", joint["joint_carbon_delta_pct"],
         "carbon saved by joint vs sequential pre-shift (solver-level; "
         ">= 0 structural via the best-of safeguard)"),
        ("sim_stream_slowdown_h364_vs_h56", stream_slowdown,
         "streaming days/s at H=56 over H=364; target <= 1.3 (O(1) "
         "day-step cost in history length)"),
        ("sim_streaming_forecast_drift", stream_drift,
         "14-day dual-run streaming-vs-rescan forecast drift; "
         "target < 0.35 (documented estimator-difference tolerance)"),
        ("sim_predictor_vs_hist_bytes_h364",
         rec["predictor_bytes_h364"] / rec["replaced_hist_bytes_h364"],
         f"PredictorState {rec['predictor_bytes_h364']}B vs replaced "
         f"hist_* {rec['replaced_hist_bytes_h364']}B at H=364; "
         "target < 1 (strictly smaller)"),
        ("sim_telemetry_overhead_pct", tel["telemetry_overhead_pct"],
         f"telemetry-on rollout vs off ({tel['telemetry_rollout_on_s']:.3f}s"
         f" vs {tel['telemetry_rollout_off_s']:.3f}s); target < 15%"),
        ("sim_telemetry_hlo_identical",
         1.0 if tel["telemetry_hlo_identical"] else 0.0,
         "telemetry-off day-step HLO vs the pre-telemetry graph; "
         "1.0 = byte-identical (collapse contract)"),
        ("sim_mpc_resolve_cost_ratio", mpc["mpc_resolve_cost_ratio"],
         f"hourly suffix re-solve vs full day solve "
         f"({mpc['mpc_suffix_solve_ms']:.2f}ms vs "
         f"{mpc['mpc_full_solve_ms']:.2f}ms); target < 1/24"),
        ("sim_mpc_carbon_delta_pct", mpc["mpc_carbon_delta_pct"],
         "mean closed-vs-open-loop carbon saved across forecast-busting "
         f"rows (flex24h delta {mpc['mpc_flex24h_delta_pp']:+.2f}pp)"),
        ("sim_mpc_hlo_identical",
         1.0 if mpc["mpc_hlo_identical"] else 0.0,
         "mpc-off day-step HLO vs the pre-MPC open-loop graph; "
         "1.0 = byte-identical (collapse contract)"),
    ]
    for r in tel["stage_costs"]:
        out.append((f"sim_stagecost_{r['stage']}_ms", r["wall_ms"],
                    f"{r['pct']:.1f}% of summed stage wall time "
                    f"(dot {r['dot_flops'] / 1e9:.3f} GFLOP)"))
    for r in hor_rows:
        out.append((f"sim_{r['mode']}_days_per_sec_h{r['horizon_days']}",
                    r["days_per_sec"],
                    f"state {r['state_bytes']}B per rollout"))
    for r in rows:
        out.append((f"sim_{r['scenario']}_carbon_saved_pct",
                    r["carbon_saved_pct"],
                    f"peakRed={r['peak_reduction_pct']:.2f}% "
                    f"flex24h={r['flex_within_24h_pct']:.2f}%"))
    for r in risk_rows:
        out.append((f"sim_{r['scenario']}_k{r['n_members']}"
                    "_carbon_saved_pct",
                    r["carbon_saved_pct"],
                    f"K={r['n_members']} "
                    f"flexDone={r['flex_completion_pct']:.2f}% "
                    f"flex24h={r['flex_within_24h_pct']:.2f}%"))
    for r in mob_rows:
        out.append((f"sim_{r['scenario']}_joint_vs_seq_pct",
                    r["carbon_vs_sequential_pct"],
                    f"carbonSaved={r['carbon_saved_pct']:.2f}% "
                    f"flex24h={r['flex_within_24h_pct']:.2f}% "
                    "(rollout-level joint-vs-sequential carbon delta)"))
    for r in mpc["mpc_rows"]:
        # gate helper in main(): closed loop must improve carbon OR
        # within-24h flex on every forecast-busting row — encode "best of
        # the two deltas" as the gated scalar
        out.append((f"sim_{r['scenario']}_mpc_vs_open_best",
                    max(r["carbon_vs_open_pct"], r["flex24h_vs_open_pp"]),
                    f"carbon {r['carbon_vs_open_pct']:+.2f}% / flex24h "
                    f"{r['flex24h_vs_open_pp']:+.2f}pp vs open loop"))
    return out


def _gate(failures, measured, op, threshold, desc):
    """CI gate: PASS iff ``measured <op> threshold``. A failure message
    always prints the measured value against the gate threshold (the
    actionable context), then the consequence ``desc``."""
    ok = {"<": measured < threshold, "<=": measured <= threshold,
          ">": measured > threshold, ">=": measured >= threshold}[op]
    if not ok:
        failures.append(
            f"measured {measured:.4g} violates gate '{op} {threshold:g}': "
            f"{desc}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small CI smoke config; exits 1 on throughput "
                         "regression or legacy/engine drift")
    ap.add_argument("--out", type=Path, default=None,
                    help="output json path (default: repo-root "
                         "BENCH_sim.json)")
    args = ap.parse_args()
    rows = run(quick=args.quick, out_path=args.out)
    by_name = {name: val for name, val, _ in rows}
    for name, val, derived in rows:
        print(f"{name},{float(val):.4f},{derived}")
    if args.quick:
        failures = []
        _gate(failures, by_name["sim_batched_speedup"], ">=", 1.5,
              "batched engine speedup (x) over the legacy loop regressed")
        _gate(failures, by_name["sim_legacy_engine_drift"], "<=", 1e-5,
              "legacy/engine drift: the two day-cycle paths forked")
        _gate(failures, by_name["sim_ensemble_solve_cost_ratio"], "<", 4.0,
              "K=8 CVaR solve cost over the K=1 solve: the member axis "
              "is no longer amortized")
        _gate(failures, by_name["sim_joint_solve_cost_ratio"], "<", 3.0,
              "joint spatio-temporal solve cost over the temporal-only "
              "solve")
        _gate(failures, by_name["sim_joint_carbon_delta_pct"], ">=", -1e-6,
              "joint solve emits MORE carbon than the sequential "
              "pre-shift (the best-of safeguard in spatial.solve_joint "
              "is broken)")
        _gate(failures, by_name["sim_stream_slowdown_h364_vs_h56"], "<=",
              1.3,
              "streaming day-step slowdown from H=56 to H=364: the "
              "streaming path is no longer O(1) in history length")
        _gate(failures, by_name["sim_streaming_forecast_drift"], "<", 0.35,
              "streaming-vs-rescan forecast drift over the 14-day dual "
              "run (the streaming estimators forked from the rescan "
              "pipeline)")
        _gate(failures, by_name["sim_predictor_vs_hist_bytes_h364"], "<",
              1.0,
              "PredictorState is not strictly smaller than the seven "
              "replaced hist_* arrays at H=364")
        _gate(failures, by_name["sim_telemetry_hlo_identical"], ">=", 1.0,
              "telemetry-off day-step HLO is no longer byte-identical "
              "to the pre-telemetry legacy graph (collapse contract)")
        _gate(failures, by_name["sim_telemetry_overhead_pct"], "<", 15.0,
              "telemetry-on rollout overhead (%) over telemetry-off")
        _gate(failures, by_name["sim_mpc_resolve_cost_ratio"], "<",
              1.0 / 24.0,
              "hourly suffix re-solve cost over the full day solve: 24 "
              "re-solves would exceed one extra day-ahead solve")
        _gate(failures, by_name["sim_mpc_hlo_identical"], ">=", 1.0,
              "mpc-off day-step HLO is no longer byte-identical to the "
              "pre-MPC open-loop graph (collapse contract)")
        for name, val, _ in rows:
            if name.endswith("_mpc_vs_open_best"):
                _gate(failures, val, ">=", 0.0,
                      f"{name}: the closed loop improved NEITHER carbon "
                      "nor within-24h flex service on a forecast-busting "
                      "row")
        for name, val, _ in rows:
            # Rollout-level tripwire, NOT a structural property: the
            # best-of safeguard guarantees plan-level dominance (gated
            # exactly above via sim_joint_carbon_delta_pct), but realized
            # carbon after sampled load + admission feedback can wiggle
            # either way. A generous tolerance catches gross regressions
            # (joint plans that systematically realize worse) without
            # flaking on admission-path noise.
            if name.endswith("_joint_vs_seq_pct"):
                _gate(failures, val, ">=", -0.5,
                      f"{name}: joint rollouts emitted substantially "
                      "more carbon than sequential pre-shift rollouts")
        if BENCH_PATH.exists():
            # Ratcheting per-member regression gate, machine-normalized:
            # the K=8-vs-K=1 cost ratio is a same-run relative measure,
            # so comparing against the committed baseline's ratio is
            # robust to CI runners being slower than the box that wrote
            # BENCH_sim.json. At a baseline near the 4.0 hard cap the
            # absolute gate binds first; as the baseline improves this
            # clause takes over (1.5x the *achieved* ratio). Uniform
            # slowdowns (K=1 and K=8 both Nx slower) are covered by the
            # batched-vs-legacy speedup gate above; absolute per-member
            # clusters/sec is recorded in the json but not CI-gated —
            # cross-machine wall-clock comparisons flake.
            base = json.loads(BENCH_PATH.read_text())
            base_ratio = base.get("ensemble_solve_cost_ratio")
            if base_ratio:
                _gate(failures,
                      by_name["sim_ensemble_solve_cost_ratio"], "<=",
                      1.5 * base_ratio,
                      "per-member ensemble throughput regressed vs the "
                      f"committed BENCH_sim.json baseline ratio "
                      f"{base_ratio:.2f}x")
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            raise SystemExit(1)
        print("quick smoke OK")


if __name__ == "__main__":
    main()
