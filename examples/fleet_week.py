"""A week of fleet operation with the randomized controlled experiment
(paper Fig 12): half the cluster-days are shaped, half are control; report
the power drop during peak-carbon hours and the SLO ledger.

    PYTHONPATH=src python examples/fleet_week.py [--days 7] [--clusters 16]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from benchmarks.fleet_bench import fig12_controlled_experiment  # noqa: E402
from repro.core import fleet as F, slo  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=7)
    ap.add_argument("--clusters", type=int, default=16)
    args = ap.parse_args()
    rows = fig12_controlled_experiment(n_clusters=args.clusters,
                                       days=args.days)
    for name, val, derived in rows:
        print(f"{name}: {val:.3f}   ({derived})")
    print("\nfull-shaping week (all clusters treated):")
    cfg = F.FleetConfig(n_clusters=args.clusters, n_campuses=4, n_zones=4,
                        lambda_e=0.6, seed=2)
    st = F.init_fleet(cfg)
    for d in range(args.days):
        rec = {}
        st = F.day_cycle(st, rec)
        res = rec["result"]
        shaped = int(np.asarray(rec["sol"].shaped
                                & st.shaping_allowed).sum())
        print(f"  day {d}: shaped={shaped}/{args.clusters} "
              f"served={float(res.served.sum()):.0f} "
              f"carbon={float(res.carbon.sum()):.0f} kgCO2e "
              f"queue={float(st.queue.sum()):.0f}")
    rate = float(slo.violation_rate(st.slo_state).mean())
    print(f"SLO violation rate: {rate:.3f} (target <= 0.03 in steady "
          "state; early operation is noisier)")


if __name__ == "__main__":
    main()
