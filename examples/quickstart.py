"""Quickstart: one CICS day on a small synthetic fleet.

Shows the paper's full pipeline end-to-end — carbon forecast, power-model
fit, load forecasts, risk-aware VCC optimization, Borg-like admission — and
prints the cluster-level result: VCC dips where carbon peaks, flexible work
shifts to green hours, daily totals conserved.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import fleet as F  # noqa: E402


def main():
    print("== CICS quickstart: init fleet (incl. 91-day telemetry burn-in)")
    cfg = F.FleetConfig(n_clusters=8, n_campuses=2, n_zones=2, lambda_e=0.6,
                        seed=0)
    st = F.init_fleet(cfg)
    rec = {}
    st = F.day_cycle(st, rec)
    sol, res, eta = rec["sol"], rec["result"], rec["intensity"]
    shaped = np.asarray(sol.shaped & st.shaping_allowed)
    print(f"shaped clusters: {shaped.sum()}/{cfg.n_clusters}")
    c = int(np.nonzero(shaped)[0][0])
    print(f"\ncluster {c} — hourly view (paper Fig 3):")
    print(f"{'h':>3} {'carbon':>7} {'VCC':>7} {'flex':>6} {'inflex':>7}")
    vcc = np.asarray(rec['vcc'][c])
    flex = np.asarray(res.usage_flex[c])
    uif = np.asarray(res.usage_total[c] - res.usage_flex[c])
    for h in range(24):
        bar = "#" * int(np.asarray(eta[c])[h] * 40)
        print(f"{h:3d} {np.asarray(eta[c])[h]:7.3f} {vcc[h]:7.2f} "
              f"{flex[h]:6.2f} {uif[h]:7.2f}  {bar}")
    corr = np.corrcoef(np.asarray(sol.delta[c]), np.asarray(eta[c]))[0, 1]
    print(f"\ncorr(delta, carbon) = {corr:.2f}  (negative = load shifted "
          "away from dirty hours)")
    print(f"flexible served / arrived: {float(res.served[c]):.1f} / "
          f"{float(res.arrived[c]):.1f} CPU-h (daily total conserved)")


if __name__ == "__main__":
    main()
