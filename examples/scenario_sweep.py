"""Scenario sweep: the whole library x seeds in ONE vmap'd batch.

Runs >= 8 scenarios x 4 seeds of multi-week CICS rollouts in a single
batched call (burn-in + rollout compiled once, scanned over days, vmapped
over the scenario-seed axis), then prints the per-scenario table of carbon
saved vs. the unshaped counterfactual, peak-power reduction, and
flexible-work completion within 24h.

    PYTHONPATH=src python examples/scenario_sweep.py [--days 14] [--seeds 4]
                                                     [--sharded]

``--sharded`` runs the same batch through `rollout_batch_sharded`: the
(scenario x seed) axis is shard_map'd over every local device (bitwise
identical results — the engine's parity contract — so the table does not
change, only the wall clock on multi-device hosts).

Reading the table: carbon-priced scenarios trade peak power for carbon
(negative peakRed% — the 'War of the Efficiencies'); `peak_shaver` flips
the prices and the sign.

``--risk`` swaps in the risk-sweep family (`risk_sweep_library`): CVaR
tail fraction beta in {0.5, 0.9, 0.99} under drought + surge, run once
per ensemble size K in RISK_MEMBERS = {1, 8, 32} (K is a static shape —
one compile each; beta is a data leaf — the sweep batches). K=1 is the
degenerate control: every beta row is identical to the point-forecast
path.

``--spatial`` swaps in the mobility-sweep family
(`mobility_sweep_library`): spatial mobility in {0, 10, 30, 60}% under a
zone-0 renewable drought + demand surge, run TWICE over the same batch —
once with the joint spatio-temporal optimizer
(`SimConfig(joint_spatial=True)`: delta and the budget shift descended
together, bounds recomputed from the shifted budgets in the fused step)
and once with the sequential greedy pre-shift. The vsSeq% column is the
carbon the joint optimizer saves over the sequential two-phase baseline;
mobility=0 is the temporal-only control row (the shift is pinned to
zero; the joint path may still refine delta, so the rows agree to float
tolerance, not bitwise).

``--telemetry`` reruns the default library with the in-graph
DayTelemetry record stacked into the rollout (`SimConfig(telemetry=
True)`) and prints a second table of solver convergence and forecast
calibration per scenario (see README "Observability"); ``--trace PATH``
additionally exports the raw per scenario x seed x day records as JSONL
— the same artifact CI uploads from the bench smoke job.
"""
import argparse
import time

import jax

from repro.sim import (MOBILITY_COLUMNS, RISK_COLUMNS, RISK_MEMBERS,
                       SimConfig, TELEMETRY_COLUMNS, build_batch,
                       default_library, format_table,
                       mobility_sweep_library, mobility_sweep_rows,
                       risk_sweep_library, risk_sweep_rows, rollout_batch,
                       rollout_batch_sharded, scenario_rows,
                       telemetry_records, telemetry_rows, write_jsonl)


def run_risk_sweep(args):
    scenarios = risk_sweep_library(args.days)
    seeds = list(range(args.seeds))
    engine = rollout_batch_sharded if args.sharded else rollout_batch
    ledgers_by_k = {}
    for k in RISK_MEMBERS:
        cfg = SimConfig(n_clusters=args.clusters, n_campuses=4, n_zones=4,
                        pds_per_cluster=2, hist_days=args.hist,
                        n_members=k)
        batch = build_batch(cfg, scenarios, seeds, args.days)
        t0 = time.time()
        _, led, _ = engine(cfg, args.days)(batch)
        jax.block_until_ready(led)
        print(f"K={k}: {len(scenarios) * len(seeds)} rollouts in "
              f"{time.time() - t0:.1f}s incl. compile")
        ledgers_by_k[k] = led
    rows = risk_sweep_rows(ledgers_by_k, [s.name for s in scenarios],
                           len(seeds))
    for r in rows:
        r["scenario"] = f"K={r['n_members']:<3d} {r['scenario']}"
    print()
    print(format_table(rows, RISK_COLUMNS))
    print("\n(risk_beta = averaged worst-tail fraction: smaller = more "
          "risk-averse; K=1 rows are the degenerate point-forecast "
          "control)")


def run_mobility_sweep(args):
    scenarios = mobility_sweep_library(args.days)
    seeds = list(range(args.seeds))
    engine = rollout_batch_sharded if args.sharded else rollout_batch
    ledgers = {}
    for joint in (True, False):
        cfg = SimConfig(n_clusters=args.clusters, n_campuses=4, n_zones=4,
                        pds_per_cluster=2, hist_days=args.hist,
                        joint_spatial=joint)
        batch = build_batch(cfg, scenarios, seeds, args.days)
        t0 = time.time()
        _, led, _ = engine(cfg, args.days)(batch)
        jax.block_until_ready(led)
        mode = "joint" if joint else "sequential"
        print(f"{mode}: {len(scenarios) * len(seeds)} rollouts in "
              f"{time.time() - t0:.1f}s incl. compile")
        ledgers[joint] = led
    rows = mobility_sweep_rows(ledgers[True], ledgers[False],
                               [s.name for s in scenarios], len(seeds))
    print()
    print(format_table(rows, MOBILITY_COLUMNS))
    print("\n(vsSeq% = carbon the joint spatio-temporal optimizer saves "
          "over the sequential greedy pre-shift on the same rollouts; "
          "mobility000 is the temporal-only control)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=14)
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--hist", type=int, default=28)
    ap.add_argument("--sharded", action="store_true",
                    help="shard the (scenario x seed) batch over all "
                         "local devices (bitwise-identical results)")
    ap.add_argument("--risk", action="store_true",
                    help="run the CVaR risk-sweep family (beta x K) "
                         "instead of the default library")
    ap.add_argument("--spatial", action="store_true",
                    help="run the mobility-sweep family through the joint "
                         "spatio-temporal optimizer vs the sequential "
                         "pre-shift")
    ap.add_argument("--telemetry", action="store_true",
                    help="stack the in-graph DayTelemetry record per day "
                         "(SimConfig(telemetry=True)) and print the "
                         "per-scenario solver/forecast diagnostics table")
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="with --telemetry: also write the per scenario x "
                         "seed x day trace records to PATH as JSONL")
    args = ap.parse_args()
    if args.days < 1 or args.seeds < 1:
        ap.error("--days and --seeds must be >= 1")
    if args.risk and args.spatial:
        ap.error("--risk and --spatial are mutually exclusive")
    if args.trace and not args.telemetry:
        ap.error("--trace requires --telemetry")
    if args.telemetry and (args.risk or args.spatial):
        ap.error("--telemetry applies to the default scenario library")
    if args.risk:
        run_risk_sweep(args)
        return
    if args.spatial:
        run_mobility_sweep(args)
        return

    cfg = SimConfig(n_clusters=args.clusters, n_campuses=4, n_zones=4,
                    pds_per_cluster=2, hist_days=args.hist,
                    telemetry=args.telemetry)
    scenarios = default_library(args.days)
    seeds = list(range(args.seeds))
    mode = (f"shard_map'd over {len(jax.devices())} device(s)"
            if args.sharded else "one vmap'd batch")
    print(f"{len(scenarios)} scenarios x {len(seeds)} seeds x "
          f"{args.days} days ({cfg.n_clusters} clusters, "
          f"{cfg.hist_days}-day burn-in) in {mode}...")

    batch = build_batch(cfg, scenarios, seeds, args.days)
    run = (rollout_batch_sharded if args.sharded
           else rollout_batch)(cfg, args.days)
    t0 = time.time()
    _, ledgers, traj = run(batch)
    jax.block_until_ready(ledgers)
    wall = time.time() - t0
    n_rollouts = len(scenarios) * len(seeds)
    print(f"{n_rollouts} rollouts ({n_rollouts * args.days} fleet-days) "
          f"in {wall:.1f}s incl. compile\n")

    rows = scenario_rows(ledgers, [s.name for s in scenarios], len(seeds))
    print(format_table(rows))
    print("\n(+carbonSaved% = shaped fleet emitted less than the unshaped "
          "counterfactual; flex<24h% = flexible work completed within a "
          "day, paper SLO)")

    if args.telemetry:
        records = telemetry_records(traj["telemetry"],
                                    [s.name for s in scenarios], len(seeds))
        print()
        print(format_table(telemetry_rows(records), TELEMETRY_COLUMNS))
        print("\n(objDec% = PGD objective decrease across the dual-ascent "
              "rounds; thetaCov/uifQCov = forecast-bound coverage of the "
              "realized day; vccBind = fraction of hours admission is "
              "pinned at the VCC; queueAge = backlog in days of service)")
        if args.trace:
            write_jsonl(args.trace, records)
            print(f"\n{len(records)} trace records "
                  f"(scenario x seed x day) -> {args.trace}")


if __name__ == "__main__":
    main()
