"""Carbon-aware batched serving: flexible batch-inference requests are
admitted under a VCC-derived gate while the model decodes with a KV cache.

    PYTHONPATH=src python examples/serve_shaped.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    serve.main(["--arch", "qwen3-0.6b", "--smoke", "--batch", "4",
                "--prompt-len", "24", "--gen", "16", "--rounds", "4",
                "--carbon-aware"])
