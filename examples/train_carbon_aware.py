"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps under carbon-aware (VCC-gated) step pacing, with
checkpoint/restart.

The trainer is the canonical *flexible workload* of the paper: its hourly
step budget follows a single-cluster VCC derived from simulated grid carbon
intensity; the daily step budget is conserved (time-shifted, not reduced).

    PYTHONPATH=src python examples/train_carbon_aware.py [--steps 300]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch import train as T  # noqa: E402
from repro.models import param_specs  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128,
                    help="CPU demo default; a real run uses >=1024")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_carbon_train")
    args = ap.parse_args()

    # ~100M config: qwen3 family scaled (12 layers, d=512, vocab 32k)
    arch = get_arch("qwen3-0.6b")
    cfg = arch.config.replace(
        name="qwen3-100m", num_layers=12, d_model=512, d_ff=1536,
        vocab_size=32768, dtype="float32", remat="none",
        attn=arch.config.attn.__class__(num_heads=8, num_kv_heads=4,
                                        head_dim=64, qk_norm=True,
                                        rope_theta=1e6))
    import numpy as np
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(param_specs(cfg)))
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    # reuse the production trainer loop with this config via its CLI
    argv = ["--arch", "qwen3-0.6b", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--carbon-aware", "--ckpt-dir", args.ckpt_dir,
            "--steps-per-hour", "25", "--lr", "3e-3", "--smoke"]
    # swap in the 100M config by monkey-patching the registry entry
    import repro.launch.train as trainmod
    import repro.configs as C
    arch100 = C.base.Arch(config=cfg, smoke=cfg)
    orig = C.get_arch

    def patched(name):
        return arch100 if name == "qwen3-0.6b" else orig(name)

    trainmod.get_arch = patched
    losses = trainmod.main(argv)
    print(f"loss trajectory: {losses[:3]} ... {losses[-3:]}")
    assert losses[-1] < losses[0], "training must improve"
    print("done — resume by re-running (checkpoints in "
          f"{args.ckpt_dir})")


if __name__ == "__main__":
    main()
