"""Sharded checkpoint/restore with atomic commit + mesh-elastic restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, step
        arrays/<idx>.npy    # one file per leaf (host-local full arrays)
        COMMIT              # written last — a checkpoint without it is
                            # ignored (crash-safe atomicity)

Restore is mesh-agnostic: leaves are saved unsharded (gathered) with their
logical shapes, and `restore` re-device_puts them under whatever shardings
the (possibly different-size) new mesh prescribes — elastic scaling.
A background thread makes `save` non-blocking (async checkpointing).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import ml_dtypes
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_savable(x: np.ndarray) -> np.ndarray:
    """numpy can't serialize ml_dtypes (bf16 etc.); view as uint bits."""
    if x.dtype == ml_dtypes.bfloat16:
        return x.view(np.uint16)
    return x


def _from_savable(x: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return x.view(ml_dtypes.bfloat16)
    return x


def save(ckpt_dir, step: int, tree, *, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Write a checkpoint. async_=True returns the writer thread."""
    ckpt_dir = Path(ckpt_dir)
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    treedef_str = str(treedef)

    def _write():
        final = ckpt_dir / f"step_{step:08d}"
        tmp = ckpt_dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        manifest = {"step": step, "treedef": treedef_str,
                    "leaves": [{"shape": list(x.shape),
                                "dtype": str(x.dtype)}
                               for x in host_leaves]}
        for i, x in enumerate(host_leaves):
            np.save(tmp / "arrays" / f"{i}.npy", _to_savable(x))
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "COMMIT").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if
                   (p / "COMMIT").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if (p / "COMMIT").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, example_tree, shardings=None):
    """Load leaves and place them under `shardings` (or uncommitted)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    assert (d / "COMMIT").exists(), f"uncommitted checkpoint {d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(example_tree)
    assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        x = np.load(d / "arrays" / f"{i}.npy")
        x = _from_savable(x, manifest["leaves"][i]["dtype"])
        assert tuple(x.shape) == tuple(ref.shape), (i, x.shape, ref.shape)
        if sh is not None:
            out.append(jax.device_put(x, sh))
        else:
            out.append(jax.device_put(x.astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)
