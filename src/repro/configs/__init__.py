"""Architecture registry: 10 assigned architectures + shapes.

Usage::

    from repro.configs import get_arch, ARCHS, SHAPES
    arch = get_arch("yi-6b")
    arch.config    # full public config (dry-run only)
    arch.smoke     # reduced same-family config (CPU tests)
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (Arch, AttentionConfig, MLAConfig, ModelConfig,
                                MoEConfig, RWKVConfig, SHAPES, ShapeConfig,
                                SSMConfig)

from repro.configs import (yi_6b, deepseek_67b, qwen3_0_6b, gemma2_9b,
                           deepseek_moe_16b, deepseek_v2_236b, internvl2_2b,
                           zamba2_7b, whisper_base, rwkv6_7b)

_MODULES = (yi_6b, deepseek_67b, qwen3_0_6b, gemma2_9b, deepseek_moe_16b,
            deepseek_v2_236b, internvl2_2b, zamba2_7b, whisper_base, rwkv6_7b)

ARCHS: Dict[str, Arch] = {m.ARCH.name: m.ARCH for m in _MODULES}


def get_arch(name: str) -> Arch:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_cells():
    """All (arch, shape) dry-run cells, with skip reasons where applicable."""
    cells = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            cells.append((a.name, s.name, a.skip_shapes.get(s.name)))
    return cells


__all__ = ["Arch", "ArchsLike", "ARCHS", "SHAPES", "ShapeConfig",
           "ModelConfig", "AttentionConfig", "MLAConfig", "MoEConfig",
           "SSMConfig", "RWKVConfig", "get_arch", "list_cells"]
