"""Config dataclasses for architectures, input shapes, and runtime options.

Every assigned architecture gets one module in ``repro.configs`` exporting an
:class:`Arch` with (i) the exact public full-size config and (ii) a reduced
``smoke`` config of the same family for CPU tests. The full configs are only
ever exercised structurally (``jax.eval_shape`` / dry-run lowering).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None            # local-attention window (gemma2)
    pattern: str = "global"                 # "global" | "local_global"
    attn_softcap: Optional[float] = None    # gemma2: 50.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    # leading dense layers (DeepSeek first_k_dense_replace)
    first_dense_layers: int = 0
    dense_d_ff: int = 0
    router_aux_weight: float = 1e-3
    group_size: int = 256                   # tokens per dispatch group
    dispatch: str = "einsum"                # "einsum" (GShard) | "scatter" (opt)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 mixer (zamba2)."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" mixer: data-dependent decay via LoRA."""
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    # vector-decay GLA materializes (c, c, K) pairwise decays per chunk:
    # HBM traffic scales with c, so keep chunks small (§Perf C3)
    chunk: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | hybrid | ssm | encdec
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    act: str = "swiglu"                     # swiglu | geglu | relu2
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    post_norm: bool = False                 # gemma2 sandwich norms
    embed_scale: bool = False               # gemma2 sqrt(d) embedding scale
    # hybrid (zamba2): shared attention block applied every `attn_every`
    # ssm layers (weights shared across applications).
    attn_every: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm: number of precomputed vision-patch embeddings prepended
    vision_tokens: int = 0
    dtype: str = "bfloat16"
    remat: str = "dots"                     # none | dots | full
    # decode attention over a sequence-sharded cache via shard_map
    # (flash-decode); beyond-paper perf option, see EXPERIMENTS.md §Perf
    flash_decode: bool = False
    # max decode length the cache is allocated for; set per-shape at lowering
    max_seq: int = 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if sequence mixing cost is sub-quadratic in seq_len."""
        return self.family in ("ssm", "hybrid")


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class Arch:
    """An assigned architecture: exact config + reduced smoke variant."""
    config: ModelConfig
    smoke: ModelConfig
    # shape-name -> reason, for cells that are skipped by design
    skip_shapes: Mapping[str, str] = field(default_factory=dict)
    source: str = ""

    @property
    def name(self) -> str:
        return self.config.name

    def supported_shapes(self) -> Tuple[str, ...]:
        return tuple(s for s in SHAPES if s not in self.skip_shapes)


FULL_ATTENTION_500K_SKIP = (
    "long_500k needs sub-quadratic sequence mixing; this arch uses full "
    "(quadratic) attention in at least some layers (see DESIGN.md §4)"
)
