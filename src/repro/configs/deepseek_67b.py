"""DeepSeek-67B — llama-architecture dense GQA LM. [arXiv:2401.02954; hf]"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab_size=102400,
    attn=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                         rope_theta=10_000.0),
    act="swiglu",
)

_SMOKE = _CFG.replace(
    name="deepseek-67b-smoke", num_layers=3, d_model=64, d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
)
