"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6, first
layer dense. [arXiv:2401.06066; hf]"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig, MoEConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    d_ff=1408,                    # routed-expert width (per assignment)
    vocab_size=102400,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                         rope_theta=10_000.0),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_dense_layers=1, dense_d_ff=10944),
    act="swiglu",
)

_SMOKE = _CFG.replace(
    name="deepseek-moe-16b-smoke", num_layers=3, d_model=64, d_ff=48,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, num_shared=1,
                  first_dense_layers=1, dense_d_ff=160, group_size=32),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
)
