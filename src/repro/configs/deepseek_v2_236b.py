"""DeepSeek-V2-236B — MLA (kv_lora=512) + fine-grained MoE: 2 shared + 160
routed top-6, first layer dense. [arXiv:2405.04434; hf]"""
from repro.configs.base import (Arch, AttentionConfig, MLAConfig, ModelConfig,
                                MoEConfig, FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    d_ff=1536,                    # routed-expert width (per assignment)
    vocab_size=102400,
    attn=AttentionConfig(num_heads=128, num_kv_heads=128, head_dim=128,
                         rope_theta=10_000.0),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                  first_dense_layers=1, dense_d_ff=12288),
    act="swiglu",
)

_SMOKE = _CFG.replace(
    name="deepseek-v2-236b-smoke", num_layers=3, d_model=64, d_ff=48,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=32),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                  nope_head_dim=32, v_head_dim=32),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, num_shared=1,
                  first_dense_layers=1, dense_d_ff=160, group_size=32),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)
