"""Gemma2-9B — local+global alternating attention, logit softcaps, sandwich
norms, GeGLU. [arXiv:2408.00118; hf]"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                         rope_theta=10_000.0, window=4096,
                         pattern="local_global", attn_softcap=50.0),
    act="geglu",
    norm_eps=1e-6,
    tie_embeddings=True,
    logit_softcap=30.0,
    post_norm=True,
    embed_scale=True,
)

_SMOKE = _CFG.replace(
    name="gemma2-9b-smoke", num_layers=2, d_model=64, d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16,
                         window=16, pattern="local_global",
                         attn_softcap=50.0),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2408.00118; hf:google/gemma-2-9b",
)
