"""InternVL2-2B — InternViT (stub frontend) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]

Per the assignment the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, vision_tokens, d_model) which the model
prepends to the token sequence.
"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    attn=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128,
                         rope_theta=1_000_000.0),
    act="swiglu",
    vision_tokens=256,
)

_SMOKE = _CFG.replace(
    name="internvl2-2b-smoke", num_layers=2, d_model=64, d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
    vision_tokens=8,
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B",
)
