"""Qwen3-0.6B — dense GQA LM with qk-norm, tied embeddings. [hf:Qwen/Qwen3-0.6B]"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    d_ff=3072,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=128,
                         qk_norm=True, rope_theta=1_000_000.0),
    act="swiglu",
    norm_eps=1e-6,
    tie_embeddings=True,
)

_SMOKE = _CFG.replace(
    name="qwen3-0.6b-smoke", num_layers=2, d_model=64, d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                         qk_norm=True, rope_theta=1_000_000.0),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="hf:Qwen/Qwen3-0.6B (family ref hf:Qwen/Qwen3-8B)",
)
