"""RWKV6-7B "Finch" — attention-free, data-dependent decay linear attention.
[arXiv:2404.05892; hf]

Sub-quadratic family: runs ``long_500k``.
"""
from repro.configs.base import (Arch, ModelConfig, RWKVConfig)

_CFG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    act="relu2",                 # RWKV channel-mix uses squared ReLU
)

_SMOKE = _CFG.replace(
    name="rwkv6-7b-smoke", num_layers=2, d_model=64, d_ff=160, vocab_size=512,
    rwkv=RWKVConfig(head_dim=16, decay_lora=16, mix_lora=8, chunk=16),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={},
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
)
