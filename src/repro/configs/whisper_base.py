"""Whisper-base — encoder-decoder with conv frontend (STUB).
[arXiv:2212.04356]

Per the assignment the conv/mel frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, d_model) for the encoder. Decoder
positions are sinusoidal (the real model uses 448 learned positions; the
substitution lets 32k-cache decode shapes lower structurally — see DESIGN.md).
"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,                 # decoder layers
    encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attn=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=64,
                         rope_theta=0.0),   # sinusoidal abs positions, no rope
    act="gelu",
    norm_eps=1e-5,
    tie_embeddings=True,
)

_SMOKE = _CFG.replace(
    name="whisper-base-smoke", num_layers=2, encoder_layers=2, encoder_seq=30,
    d_model=64, d_ff=160, vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16,
                         rope_theta=0.0),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2212.04356; hf:openai/whisper-base (unverified tier)",
)
