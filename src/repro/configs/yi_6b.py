"""Yi-6B — llama-architecture dense GQA LM. [arXiv:2403.04652; hf]"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig,
                                FULL_ATTENTION_500K_SKIP)

_CFG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=64000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=4, head_dim=128,
                         rope_theta=5_000_000.0),
    act="swiglu",
)

_SMOKE = _CFG.replace(
    name="yi-6b-smoke", num_layers=2, d_model=64, d_ff=160, vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16,
                         rope_theta=5_000_000.0),
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={"long_500k": FULL_ATTENTION_500K_SKIP},
    source="arXiv:2403.04652; hf:01-ai/Yi-6B",
)
