"""Zamba2-7B — Mamba2 backbone with shared (weight-tied) attention blocks
interleaved. [arXiv:2411.15242]

81 Mamba2 layers; one shared transformer block (attention + MLP, weights
shared across applications) applied after every ``attn_every`` = 6 Mamba2
layers (13 applications; the trailing 3 layers are pure Mamba2).
Sub-quadratic family: runs ``long_500k``.
"""
from repro.configs.base import (Arch, AttentionConfig, ModelConfig, SSMConfig)

_CFG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=112,
                         rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    act="geglu",
    attn_every=6,
)

_SMOKE = _CFG.replace(
    name="zamba2-7b-smoke", num_layers=7, d_model=64, d_ff=160,
    vocab_size=512,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    attn_every=3,
)

ARCH = Arch(
    config=_CFG,
    smoke=_SMOKE,
    skip_shapes={},
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-7B (unverified tier)",
)
