"""CICS — Carbon-Intelligent Compute management (the paper's contribution).

Pipelines (paper Fig. 4): carbon fetching (carbon.py), power models
(power.py), load forecasting (forecast.py), risk-aware VCC optimization
(vcc.py), forecast ensembles + CVaR risk objective (risk.py), SLO
violation detection (slo.py), Borg-like admission under VCCs
(admission.py), and the beyond-paper spatial layer (spatial.py: greedy
pre-shift + joint spatio-temporal optimization). Every optimizer is an
assembly over the ONE generic projected-gradient layer (solver.py:
projections, smooth peak, lr scaling, dual ascent, kernel-epoch
dispatch). ``stages.py`` composes the pipelines into THE staged day cycle
(pure stage functions -> one pure day step) shared by both drivers;
``fleet.py`` is the legacy mutable-FleetState adapter over it.
"""
from repro.core import (admission, carbon, fleet, forecast, power, risk,
                        slo, solver, spatial, stages, vcc)

__all__ = ["admission", "carbon", "fleet", "forecast", "power", "risk",
           "slo", "solver", "spatial", "stages", "vcc"]
