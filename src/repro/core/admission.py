"""Borg-like real-time admission control under a VCC (paper §II-B/§II-C).

The cluster scheduler is modeled at the fidelity the paper's mechanism
needs: jobs "flow like fluid into containers" — inflexible (higher-tier)
work is always admitted; flexible (lower-tier) work is admitted from a queue
only while total RESERVATIONS stay under the hour's VCC. Queued flexible
work is revisited every tick and completes within the day when capacity
allows. The VCC changes only the scheduler's perception of available
capacity — the admission policy itself is untouched (scheduler-agnostic).

Vectorized across clusters; scanned over 24 hourly ticks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

f32 = jnp.float32


def hour_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Ordered sum over the trailing 24-hour axis. XLA's `.sum()` picks a
    batch-extent-dependent accumulation order; daily totals feed SLO
    thresholds, so they must be bitwise-stable under vmap (sim engine)."""
    out = x[..., 0]
    for h in range(1, x.shape[-1]):
        out = out + x[..., h]
    return out


@dataclass
class DayResult:
    usage_flex: jnp.ndarray     # (n, 24) flexible CPU usage
    usage_total: jnp.ndarray    # (n, 24)
    reservations: jnp.ndarray   # (n, 24) total reservations
    power: jnp.ndarray          # (n, 24) kW
    carbon: jnp.ndarray         # (n, 24) kgCO2e
    served: jnp.ndarray         # (n,) flexible CPU-h served
    arrived: jnp.ndarray        # (n,) flexible CPU-h arrived
    queue_end: jnp.ndarray      # (n,)
    unmet: jnp.ndarray          # (n,) arrivals not served within the day


# Pytree registration: the staged day step returns DayResults across jit
# boundaries (stages.StepOut), so the fields must be data leaves.
jax.tree_util.register_dataclass(
    DayResult,
    data_fields=["usage_flex", "usage_total", "reservations", "power",
                 "carbon", "served", "arrived", "queue_end", "unmet"],
    meta_fields=[])


def admission_tick(queue, vcc_h, uif_h, arr_h, r_h, capacity):
    """One hourly admission decision for all clusters: (queue', use_flex).

    Shared by ``run_day``'s 24-tick scan and the MPC recourse loop
    (``core.mpc``), so the intra-day controller can never fork from the
    open-loop admission semantics."""
    # inflexible is always admitted (possibly beyond VCC — by design
    # shaping must never impact it); flexible gets the remainder.
    flex_room_res = jnp.clip(vcc_h - uif_h * r_h, 0.0, None)
    flex_room = flex_room_res / jnp.clip(r_h, 1.0, None)
    # machine capacity is a hard cap on usage
    flex_room = jnp.minimum(flex_room,
                            jnp.clip(capacity - uif_h, 0.0, None))
    demand = queue + arr_h
    use_flex = jnp.minimum(demand, flex_room)
    queue = demand - use_flex
    return queue, use_flex


def finalize_day(use_flex, queue_end, u_if, arrivals, ratio, queue0,
                 power_fn, intensity, allowance_frac: float = 0.25
                 ) -> DayResult:
    """Assemble the DayResult from realized hourly flexible usage — the
    single definition of the day's power/carbon/SLO accounting, used by
    both the open-loop ``run_day`` and the hourly MPC loop.

    ``allowance_frac``: SLO semantics (paper): flexible work completes
    within 24h. Work that arrived late today may legitimately run
    tomorrow morning; count as unmet only the backlog growth beyond a
    late-day allowance of ``allowance_frac * arrived`` (the report layer
    surfaces the value the gate was computed against)."""
    usage_total = u_if + use_flex
    reservations = usage_total * ratio
    power = jax.vmap(power_fn, in_axes=1, out_axes=1)(usage_total)
    carbon = power * intensity
    arrived = hour_sum(arrivals)
    served = hour_sum(use_flex)
    allowance = allowance_frac * arrived
    unmet = jnp.clip(queue_end - queue0 - allowance, 0.0, None)
    return DayResult(usage_flex=use_flex, usage_total=usage_total,
                     reservations=reservations, power=power, carbon=carbon,
                     served=served, arrived=arrived, queue_end=queue_end,
                     unmet=unmet)


def run_day(vcc, u_if, arrivals, ratio, capacity, queue0, power_fn,
            intensity, allowance_frac: float = 0.25) -> DayResult:
    """Simulate one day for all clusters.

    vcc, u_if, arrivals, ratio: (n, 24); capacity: (n,); queue0: (n,)
    power_fn: (u_total (n,)) -> power kW (n,);  intensity: (n, 24).
    """
    def tick(queue, inp):
        vcc_h, uif_h, arr_h, r_h = inp
        queue, use_flex = admission_tick(queue, vcc_h, uif_h, arr_h, r_h,
                                         capacity)
        return queue, (use_flex, queue)

    xs = (vcc.T, u_if.T, arrivals.T, ratio.T)
    queue_end, (use_flex, queue_traj) = jax.lax.scan(tick, queue0, xs)
    return finalize_day(use_flex.T, queue_end, u_if, arrivals, ratio,
                        queue0, power_fn, intensity, allowance_frac)
