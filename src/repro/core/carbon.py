"""Grid simulation + day-ahead carbon-intensity forecasting (paper §III-B3).

The paper consumes hourly average carbon-intensity forecasts from Tomorrow
(electricityMap) per grid zone. Offline, we build the substrate: a
multi-zone grid simulator whose hourly average carbon intensity is driven by
a generation mix (solar/wind/hydro/nuclear/gas/coal) with diurnal structure
and AR(1) weather, plus a forecaster whose day-ahead MAPE lands in the
paper's reported 0.4%-26% band depending on zone volatility.

All series are shaped (days, 24) or (zones, days, 24); hours are UTC.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32

# kgCO2e / kWh by source (lifecycle-ish averages)
CI_BY_SOURCE = {
    "coal": 0.95, "gas": 0.45, "solar": 0.0, "wind": 0.0,
    "hydro": 0.0, "nuclear": 0.0,
}


@dataclass(frozen=True)
class ZoneConfig:
    """A grid zone's structural mix. Fractions are of mean demand."""
    name: str = "zone"
    solar_cap: float = 0.35        # midday solar peak as fraction of demand
    wind_cap: float = 0.25
    baseload: float = 0.30         # hydro+nuclear, carbon-free
    coal_share: float = 0.4        # of the thermal residual
    weather_vol: float = 0.2       # AR(1) innovation scale (forecastability)
    demand_amp: float = 0.15       # diurnal demand swing


ZONE_FIELDS = ("solar_cap", "wind_cap", "baseload", "coal_share",
               "weather_vol", "demand_amp")


def zone_params(zone: ZoneConfig) -> dict:
    """ZoneConfig -> dict of f32 scalars (the array-native scenario hook:
    sim scenarios perturb these before simulation)."""
    return {k: jnp.asarray(getattr(zone, k), f32) for k in ZONE_FIELDS}


def stack_zone_params(zones) -> dict:
    """Tuple of ZoneConfig -> dict of (n_zones,) arrays for vmapping."""
    return {k: jnp.asarray([getattr(z, k) for z in zones], f32)
            for k in ZONE_FIELDS}


def _diurnal(hours, peak_hour, width):
    d = jnp.minimum(jnp.abs(hours - peak_hour), 24 - jnp.abs(hours - peak_hour))
    return jnp.exp(-0.5 * (d / width) ** 2)


def simulate_zone_from(key, zp: dict, days: int) -> jnp.ndarray:
    """Hourly average carbon intensity from a zone-parameter dict (scalars
    or traced scalars). Shape (days, 24), kgCO2e/kWh."""
    hours = jnp.arange(24, dtype=f32)
    k1, k2, k3 = jax.random.split(key, 3)
    # AR(1) daily weather states for solar clearness and wind strength
    def ar1(key, n, rho=0.7, vol=1.0):
        eps = jax.random.normal(key, (n,)) * vol
        def step(x, e):
            x = rho * x + jnp.sqrt(1 - rho ** 2) * e
            return x, x
        _, xs = jax.lax.scan(step, jnp.zeros(()), eps)
        return xs
    clear = jax.nn.sigmoid(1.0 + ar1(k1, days, vol=zp["weather_vol"] * 5))
    windy = jax.nn.sigmoid(0.5 + ar1(k2, days, vol=zp["weather_vol"] * 6))
    demand = 1.0 + zp["demand_amp"] * (
        0.6 * _diurnal(hours, 19.0, 3.5) + 0.4 * _diurnal(hours, 9.0, 2.5))
    solar_shape = _diurnal(hours, 12.5, 2.8)
    wind_noise = 1.0 + 0.15 * jax.random.normal(k3, (days, 24))
    solar = zp["solar_cap"] * clear[:, None] * solar_shape[None, :]
    wind = zp["wind_cap"] * windy[:, None] * jnp.clip(wind_noise, 0.3, 1.7)
    green = solar + wind + zp["baseload"]
    thermal = jnp.maximum(demand[None, :] - green, 0.02)
    coal = jnp.clip(zp["coal_share"], 0.0, 1.0)
    ci_thermal = (coal * CI_BY_SOURCE["coal"]
                  + (1 - coal) * CI_BY_SOURCE["gas"])
    intensity = thermal * ci_thermal / demand[None, :]
    return intensity.astype(f32)


def simulate_zone(key, zone: ZoneConfig, days: int) -> jnp.ndarray:
    """Hourly average carbon intensity, shape (days, 24), kgCO2e/kWh."""
    return simulate_zone_from(key, zone_params(zone), days)


def simulate_zones_from(keys, zps: dict, days: int) -> jnp.ndarray:
    """Batched over zones: keys (z, 2), zps dict of (z,) -> (z, days, 24)."""
    return jax.vmap(lambda k, p: simulate_zone_from(k, p, days))(keys, zps)


def forecast_day_ahead(key, history: jnp.ndarray, actual_next: jnp.ndarray,
                       vol: float) -> jnp.ndarray:
    """Day-ahead hourly forecast for the next day.

    Blend of climatology (trailing 7-day hourly mean) and persistence
    (yesterday), plus a forecast-error term scaled by zone volatility so the
    realized MAPE spans the paper's 0.4-26% band across zones/horizons.
    history: (d, 24) past actuals; actual_next: (24,) tomorrow's truth.
    """
    clim = history[-7:].mean(axis=0)
    persist = history[-1]
    base = 0.6 * clim + 0.4 * persist
    # weather-forecast skill: forecasters see most of tomorrow's deviation
    dev = actual_next - base
    err = jax.random.normal(key, (24,)) * vol * jnp.abs(actual_next)
    return jnp.clip(base + 0.8 * dev + err, 1e-3, None).astype(f32)


def mape(forecast: jnp.ndarray, actual: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.abs(forecast - actual)
                    / jnp.clip(jnp.abs(actual), 1e-6, None))


def default_zones(n: int) -> Tuple[ZoneConfig, ...]:
    """A spread of zones from very green/volatile to coal-heavy/stable."""
    rng = np.random.RandomState(7)
    zones = []
    for i in range(n):
        zones.append(ZoneConfig(
            name=f"zone_{i}",
            solar_cap=float(rng.uniform(0.05, 0.55)),
            wind_cap=float(rng.uniform(0.05, 0.45)),
            baseload=float(rng.uniform(0.15, 0.5)),
            coal_share=float(rng.uniform(0.05, 0.8)),
            weather_vol=float(rng.uniform(0.02, 0.45)),
            demand_amp=float(rng.uniform(0.08, 0.25)),
        ))
    return tuple(zones)
