"""Legacy fleet API: mutable FleetState adapters over the staged day cycle.

The CICS day cycle itself lives in ``core/stages.py`` as pure, jit/vmap
-safe stage functions — this module owns NO pipeline math anymore. It keeps
the original ergonomic surface (a mutable ``FleetState`` you step one day
at a time, with a ``record`` dict for paper-figure probes) as thin adapters:

  * ``init_fleet``   — synthesizes the fleet (same ``stages.synth_params``
    leaves the sim scenarios use) and burns in ``hist_days`` of telemetry
    under ``lax.scan`` (one dispatch — ``init_fleet`` is jit-compiled).
  * ``day_cycle``    — converts FleetState -> (SimParams, SimState), runs
    the SAME jitted day step as ``sim.engine`` (``stages.jitted_day_step``)
    with neutral all-ones scenario slices, and writes the result back.
  * ``_observe_day`` / ``make_power_fn`` / ``day_forecasts`` /
    ``carbon_forecast_next`` / ``build_problem`` — per-stage adapters for
    custom drivers (e.g. the Fig. 12 randomized controlled experiment in
    ``benchmarks/fleet_bench.py``).

Because both paths run the same staged step, ``fleet.day_cycle`` and the
sim engine's ``day_step`` agree bitwise from the same state (tested in
tests/test_stages_parity.py). The fleet is synthetic but calibrated:
cluster-level day-ahead APE distributions match the bands of paper Fig. 7
(see benchmarks/).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import carbon, power, slo, stages, stats, vcc

f32 = jnp.float32
HIST_DAYS = 91            # 13 weeks of rolling history (default burn-in)

# re-exported synthesis + pure stage functions (legacy import sites)
cluster_truth = stages.cluster_truth
_sample_inflexible = stages.sample_inflexible
_sample_arrivals = stages.sample_arrivals
_true_ratio = stages.true_ratio
build_problem_arrays = stages.build_problem_arrays


@dataclass(frozen=True)
class FleetConfig:
    n_clusters: int = 48
    n_campuses: int = 6
    n_zones: int = 6
    pds_per_cluster: int = 4
    gamma: float = 0.05           # power-capping violation prob
    lambda_e: float = 0.08
    lambda_p: float = 0.05
    seed: int = 0
    hist_days: int = HIST_DAYS
    streaming: bool = False       # True = O(1) streaming prediction layer
    #                               (FleetState.pred carries the
    #                               stats.PredictorState; the hist_*
    #                               windows become zero-length stubs)
    telemetry: bool = False       # True = day_cycle records a
    #                               sim.telemetry DayTelemetry under
    #                               record["telemetry"]; False keeps the
    #                               legacy compiled graph byte-identical
    mpc: bool = False             # True = intra-day MPC recourse
    #                               (core.mpc hourly suffix re-solves);
    #                               False = the open-loop legacy graph
    slo: slo.SLOConfig = field(default_factory=slo.SLOConfig)


@dataclass
class FleetState:
    cfg: FleetConfig
    day: int
    key: jnp.ndarray                 # rollout PRNG key (engine convention)
    # static cluster structure
    capacity: jnp.ndarray            # (n,)
    campus: jnp.ndarray              # (n,) int
    zmap: jnp.ndarray                # (n,) int zone of cluster
    zone_of_campus: np.ndarray       # (n_campuses,)
    campus_limit: jnp.ndarray        # (n_campuses,) kW
    u_pow_cap: jnp.ndarray           # (n,)
    # latent truth for synthesis
    truth: Dict[str, jnp.ndarray]
    pd_truth: power.PDTruth
    lam: jnp.ndarray                 # (n, pds) usage fractions
    zone: Dict[str, jnp.ndarray]     # stacked grid-mix params, (zones,)
    # rolling history (oldest first)
    hist_uif: jnp.ndarray            # (n, HIST, 24)
    hist_flex_daily: jnp.ndarray     # (n, HIST)
    hist_res_daily: jnp.ndarray      # (n, HIST)
    hist_usage: jnp.ndarray          # (n, HIST, 24) total usage
    hist_res: jnp.ndarray            # (n, HIST, 24) total reservations
    hist_tr_pred: jnp.ndarray        # (n, HIST) past T_R predictions
    hist_uif_pred: jnp.ndarray       # (n, HIST, 24) past U_IF predictions
    carbon_hist: jnp.ndarray         # (zones, HIST, 24)
    queue: jnp.ndarray               # (n,)
    cf_queue: jnp.ndarray            # (n,) unshaped-counterfactual backlog
    slo_state: Dict[str, jnp.ndarray]
    shaping_allowed: jnp.ndarray     # (n,) bool
    zones: Tuple[carbon.ZoneConfig, ...] = ()
    pred: Optional[stats.PredictorState] = None   # streaming-mode carry


def _stage_cfg(cfg: FleetConfig) -> stages.StageConfig:
    return stages.StageConfig(slo_margin=cfg.slo.margin,
                              slo_pause_days=cfg.slo.pause_days,
                              streaming=cfg.streaming,
                              telemetry=cfg.telemetry,
                              mpc=cfg.mpc)


# --------------------------------------------- FleetState <-> stage pytrees

def sim_params(state: FleetState) -> stages.SimParams:
    """View a FleetState as the engine's array-only SimParams (neutral
    one-day schedules: the legacy path runs nominal operation)."""
    cfg = state.cfg
    ones = functools.partial(jnp.ones, dtype=f32)
    return stages.SimParams(
        key=state.key, truth=state.truth,
        pd_idle=state.pd_truth.idle_kw, pd_slope=state.pd_truth.slope_kw,
        pd_curve=state.pd_truth.curve, lam=state.lam, zone=state.zone,
        lambda_e=jnp.asarray(cfg.lambda_e, f32),
        lambda_p=jnp.asarray(cfg.lambda_p, f32),
        gamma=jnp.asarray(cfg.gamma, f32),
        mobility=jnp.zeros((), f32),
        risk_beta=jnp.ones((), f32),
        green_scale=ones((1, cfg.n_zones)),
        coal_scale=ones((1, cfg.n_zones)),
        cap_scale=ones((1, cfg.n_clusters)),
        arrival_scale=ones((1, cfg.n_clusters)),
        campus_scale=ones((1, cfg.n_campuses)))


def sim_state(state: FleetState) -> stages.SimState:
    """View a FleetState as the engine's array-only SimState."""
    return stages.SimState(
        day=jnp.asarray(state.day, jnp.int32),
        campus=state.campus, zmap=state.zmap,
        campus_limit=state.campus_limit, u_pow_cap=state.u_pow_cap,
        hist_uif=state.hist_uif, hist_flex_daily=state.hist_flex_daily,
        hist_res_daily=state.hist_res_daily, hist_usage=state.hist_usage,
        hist_res=state.hist_res, hist_tr_pred=state.hist_tr_pred,
        hist_uif_pred=state.hist_uif_pred, carbon_hist=state.carbon_hist,
        queue=state.queue, cf_queue=state.cf_queue,
        crowded_streak=state.slo_state["crowded_streak"],
        pause_left=state.slo_state["pause_left"],
        violation_days=state.slo_state["violation_days"],
        observed_days=state.slo_state["observed_days"],
        shaping_allowed=state.shaping_allowed,
        pred=state.pred)


def _writeback(state: FleetState, s: stages.SimState) -> FleetState:
    state.day = int(s.day)
    state.campus_limit = s.campus_limit
    state.hist_uif = s.hist_uif
    state.hist_flex_daily = s.hist_flex_daily
    state.hist_res_daily = s.hist_res_daily
    state.hist_usage = s.hist_usage
    state.hist_res = s.hist_res
    state.hist_tr_pred = s.hist_tr_pred
    state.hist_uif_pred = s.hist_uif_pred
    state.carbon_hist = s.carbon_hist
    state.queue = s.queue
    state.cf_queue = s.cf_queue
    state.slo_state = {"crowded_streak": s.crowded_streak,
                       "pause_left": s.pause_left,
                       "violation_days": s.violation_days,
                       "observed_days": s.observed_days}
    state.shaping_allowed = s.shaping_allowed
    state.pred = s.pred
    return state


# --------------------------------------------------------------- synthesis

def _cluster_truth(key, cfg: FleetConfig):
    return stages.cluster_truth(key, cfg.n_clusters)


@functools.lru_cache(maxsize=None)
def _jitted_init(n: int, m: int, z: int, hist_days: int,
                 streaming: bool = False):
    return jax.jit(stages.make_init(n, m, z, hist_days,
                                    streaming=streaming))


def init_fleet(cfg: FleetConfig) -> FleetState:
    """Synthesize + burn in a fleet. The burn-in is a single jitted
    ``lax.scan`` over ``cfg.hist_days`` unshaped days (the old eager
    Python loop cost hundreds of dispatches per day)."""
    sp = stages.synth_params(cfg.seed, cfg.n_clusters, cfg.pds_per_cluster,
                             cfg.n_zones)
    pdt = power.PDTruth(idle_kw=sp["pd_idle"], slope_kw=sp["pd_slope"],
                        curve=sp["pd_curve"])
    zone_of_campus = np.arange(cfg.n_campuses) % cfg.n_zones
    state = FleetState(
        cfg=cfg, day=0, key=sp["key"],
        capacity=sp["truth"]["capacity"],
        campus=jnp.asarray(np.arange(cfg.n_clusters) % cfg.n_campuses,
                           jnp.int32),
        zmap=jnp.asarray(zone_of_campus[np.arange(cfg.n_clusters)
                                        % cfg.n_campuses], jnp.int32),
        zone_of_campus=zone_of_campus,
        campus_limit=jnp.zeros((cfg.n_campuses,), f32),
        u_pow_cap=sp["truth"]["capacity"] * 0.95,
        truth=sp["truth"], pd_truth=pdt, lam=sp["lam"], zone=sp["zone"],
        hist_uif=jnp.zeros((cfg.n_clusters, cfg.hist_days, 24), f32),
        hist_flex_daily=jnp.zeros((cfg.n_clusters, cfg.hist_days), f32),
        hist_res_daily=jnp.zeros((cfg.n_clusters, cfg.hist_days), f32),
        hist_usage=jnp.zeros((cfg.n_clusters, cfg.hist_days, 24), f32),
        hist_res=jnp.zeros((cfg.n_clusters, cfg.hist_days, 24), f32),
        hist_tr_pred=jnp.zeros((cfg.n_clusters, cfg.hist_days), f32),
        hist_uif_pred=jnp.zeros((cfg.n_clusters, cfg.hist_days, 24), f32),
        carbon_hist=jnp.zeros((cfg.n_zones, cfg.hist_days, 24), f32),
        queue=jnp.zeros((cfg.n_clusters,), f32),
        cf_queue=jnp.zeros((cfg.n_clusters,), f32),
        slo_state=slo.init_state(cfg.n_clusters),
        shaping_allowed=jnp.ones((cfg.n_clusters,), bool),
        zones=carbon.default_zones(cfg.n_zones),
    )
    init = _jitted_init(cfg.n_clusters, cfg.n_campuses, cfg.n_zones,
                        cfg.hist_days, cfg.streaming)
    return _writeback(state, init(sim_params(state)))


# ---------------------------------------------------- per-stage adapters

def _day_key(state: FleetState, day) -> jnp.ndarray:
    return jax.random.fold_in(state.key, day)


def power_model_from_history(hist_usage, lam, capacity, pd_truth, key):
    """Back-compat wrapper over ``stages.power_stage``: returns cluster
    power/slope closures + the fitted (coef, breaks)."""
    model = stages.power_stage(hist_usage, lam, capacity, pd_truth, key)

    def cluster_power_fn(u_cluster):
        return stages.model_power(model, u_cluster)

    def cluster_slope_fn(u_cluster):
        return stages.model_slope(model, u_cluster)

    return cluster_power_fn, cluster_slope_fn, (model.coef, model.breaks)


def make_power_fn(state: FleetState):
    """Cluster power from PD piecewise models fit on recent history (the
    streaming usage ring holds the same 28-day window — identical fit)."""
    hist = state.pred.usage_ring if state.cfg.streaming else state.hist_usage
    return power_model_from_history(
        hist, state.lam, state.truth["capacity"],
        state.pd_truth, jax.random.fold_in(_day_key(state, state.day), 1))


def day_forecasts_arrays(hist_uif, hist_flex_daily, hist_res_daily,
                         hist_usage, hist_res, hist_tr_pred, hist_uif_pred,
                         day, gamma):
    """Back-compat alias of ``stages.forecast_stage``."""
    return stages.forecast_stage(hist_uif, hist_flex_daily, hist_res_daily,
                                 hist_usage, hist_res, hist_tr_pred,
                                 hist_uif_pred, day, gamma)


def day_forecasts(state: FleetState):
    """Run the forecasting pipeline for the next day (vmapped; the O(1)
    streaming pipeline when the fleet is configured for it)."""
    if state.cfg.streaming:
        return stages.forecast_stage_streaming(state.pred, state.day,
                                               state.cfg.gamma)
    return stages.forecast_stage(
        state.hist_uif, state.hist_flex_daily, state.hist_res_daily,
        state.hist_usage, state.hist_res, state.hist_tr_pred,
        state.hist_uif_pred, state.day, state.cfg.gamma)


def carbon_forecast_next(state: FleetState, day):
    """Actual + day-ahead forecast intensity per cluster for the day."""
    nz = state.carbon_hist.shape[0]
    ones = jnp.ones((nz,), f32)
    act_z, fc_z = stages.carbon_stage(state.zone, state.carbon_hist,
                                      jax.random.fold_in(
                                          _day_key(state, day), 4),
                                      ones, ones)
    return act_z, fc_z, act_z[state.zmap], fc_z[state.zmap]


def build_problem(state: FleetState, fc, eta_fc, power_fn, slope_fn
                  ) -> vcc.VCCProblem:
    return stages.build_problem_arrays(
        fc, eta_fc, power_fn, slope_fn, state.queue, state.u_pow_cap,
        state.capacity, state.campus, state.campus_limit,
        state.cfg.lambda_e, state.cfg.lambda_p)


def _observe_day(state: FleetState, day, shaped: bool,
                 vcc_curve=None, treat_mask=None, collect=False):
    """Run one actual day (optionally VCC-shaped) and roll histories.

    Adapter over ``stages.observe_stage`` for custom drivers (Fig. 12's
    randomized treatment); ``day_cycle`` runs the full staged step instead.
    Rescan fleets only: the custom drivers roll the ``hist_*`` windows
    this adapter maintains, which a streaming fleet no longer carries.
    """
    cfg = state.cfg
    if cfg.streaming:
        raise NotImplementedError(
            "_observe_day drives the rescan history windows; run custom "
            "drivers on a FleetConfig(streaming=False) fleet (day_cycle "
            "itself supports streaming)")
    n = cfg.n_clusters
    day_key = _day_key(state, day)
    power_fn, _, _ = power_model_from_history(
        state.hist_usage, state.lam, state.truth["capacity"],
        state.pd_truth, jax.random.fold_in(day_key, 1))
    if vcc_curve is None:
        vcc_curve = jnp.broadcast_to(state.capacity[:, None] * 10.0,
                                     (n, 24))
    if treat_mask is not None:
        vcc_curve = jnp.where(treat_mask[:, None], vcc_curve,
                              state.capacity[:, None] * 10.0)
    # actual carbon for the day (same draw as carbon_forecast_next)
    nz = state.carbon_hist.shape[0]
    ones_z = jnp.ones((nz,), f32)
    act_z, _ = stages.carbon_stage(state.zone, state.carbon_hist,
                                   jax.random.fold_in(day_key, 4),
                                   ones_z, ones_z)
    intensity = act_z[state.zmap]
    res, cf, u_if, _ = stages.observe_stage(
        state.truth, jnp.asarray(day, jnp.int32), day_key, vcc_curve,
        state.capacity, jnp.ones((n,), f32), state.queue, state.cf_queue,
        power_fn, intensity)
    # roll histories
    state.hist_uif = stages.roll(state.hist_uif, u_if)
    state.hist_flex_daily = stages.roll(state.hist_flex_daily, res.served)
    state.hist_res_daily = stages.roll(state.hist_res_daily,
                                       stages.hour_sum(res.reservations))
    state.hist_usage = stages.roll(state.hist_usage, res.usage_total)
    state.hist_res = stages.roll(state.hist_res, res.reservations)
    state.carbon_hist = stages.roll(state.carbon_hist, act_z)
    state.queue = res.queue_end
    state.cf_queue = cf.queue_end
    state.day = int(day) + 1
    if collect:
        return state, res, intensity
    return state


def day_cycle(state: FleetState, record: Optional[dict] = None
              ) -> FleetState:
    """One full CICS day: forecast -> optimize -> shape -> observe.

    Runs the SAME jitted staged step as the sim engine (one dispatch per
    day) with neutral scenario slices, then writes back into the mutable
    FleetState. ``record`` (if given) receives the probes the paper-figure
    benchmarks read: fc, sol, vcc, result, cf_result, intensity, problem.
    """
    cfg = state.cfg
    step = stages.jitted_day_step(_stage_cfg(cfg))
    xs = stages.ones_xs(cfg.n_clusters, cfg.n_campuses, cfg.n_zones)
    new_state, out = step(sim_params(state), sim_state(state), xs)
    state = _writeback(state, new_state)
    if record is not None:
        record.update(dict(fc=out.fc, sol=out.sol, vcc=out.vcc_curve,
                           result=out.res, cf_result=out.cf,
                           intensity=out.eta_act, problem=out.prob,
                           telemetry=out.telemetry))
    return state
