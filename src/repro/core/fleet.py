"""Fleet synthesis + the CICS day cycle (paper Fig. 4/5).

Ties the pipelines together exactly as deployed: every simulated day,

  1. carbon pipeline     — fetch day-ahead intensity forecasts per zone
  2. power pipeline      — refit piecewise-linear power models on history
  3. forecasting         — day-ahead U_IF(h), T_UF(d), T_R(d), R(h),
                           trailing-error quantiles -> Theta, alpha (eq. 3)
  4. optimization        — fleetwide risk-aware VCCs (eq. 4)
  5. SLO gate + feedback — paused clusters get VCC = machine capacity
  6. real time           — Borg-like admission under the VCC on ACTUAL load
  7. telemetry           — roll histories; update SLO state

The fleet itself is synthetic but calibrated: cluster-level day-ahead APE
distributions match the bands of paper Fig. 7 (see benchmarks/).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, carbon, forecast, power, slo, vcc

f32 = jnp.float32
HIST_DAYS = 91            # 13 weeks of rolling history


@dataclass(frozen=True)
class FleetConfig:
    n_clusters: int = 48
    n_campuses: int = 6
    n_zones: int = 6
    pds_per_cluster: int = 4
    gamma: float = 0.05           # power-capping violation prob
    lambda_e: float = 0.08
    lambda_p: float = 0.05
    seed: int = 0
    slo: slo.SLOConfig = field(default_factory=slo.SLOConfig)


@dataclass
class FleetState:
    cfg: FleetConfig
    day: int
    # static cluster structure
    capacity: jnp.ndarray            # (n,)
    campus: jnp.ndarray              # (n,) int
    zone_of_campus: np.ndarray       # (n_campuses,)
    campus_limit: jnp.ndarray        # (n_campuses,) kW
    u_pow_cap: jnp.ndarray           # (n,)
    # latent truth for synthesis
    truth: Dict[str, jnp.ndarray]
    pd_truth: power.PDTruth
    lam: jnp.ndarray                 # (n, pds) usage fractions
    # rolling history (oldest first)
    hist_uif: jnp.ndarray            # (n, HIST, 24)
    hist_flex_daily: jnp.ndarray     # (n, HIST)
    hist_res_daily: jnp.ndarray      # (n, HIST)
    hist_usage: jnp.ndarray          # (n, HIST, 24) total usage
    hist_res: jnp.ndarray            # (n, HIST, 24) total reservations
    hist_tr_pred: jnp.ndarray        # (n, HIST) past T_R predictions
    hist_uif_pred: jnp.ndarray       # (n, HIST, 24) past U_IF predictions
    carbon_hist: jnp.ndarray         # (zones, HIST, 24)
    queue: jnp.ndarray               # (n,)
    slo_state: Dict[str, jnp.ndarray]
    shaping_allowed: jnp.ndarray     # (n,) bool
    zones: Tuple[carbon.ZoneConfig, ...] = ()


# --------------------------------------------------------------- synthesis

def cluster_truth(key, n: int):
    """Latent per-cluster load-generating processes."""
    ks = jax.random.split(key, 10)
    capacity = jnp.exp(jax.random.normal(ks[0], (n,)) * 0.4 + 2.3)  # ~10 CPU
    flex_share = jnp.clip(0.08 + 0.5 * jax.random.uniform(ks[1], (n,)),
                          0.05, 0.6)
    base_if = capacity * (0.35 + 0.2 * jax.random.uniform(ks[2], (n,)))
    diurnal_amp = 0.15 + 0.2 * jax.random.uniform(ks[3], (n,))
    peak_hour = 8.0 + 10.0 * jax.random.uniform(ks[4], (n,))
    weekly_amp = 0.05 + 0.1 * jax.random.uniform(ks[5], (n,))
    noise = 0.02 + 0.06 * jax.random.uniform(ks[6], (n,))
    arr_level = capacity * flex_share * (0.5 + 0.4 *
                                         jax.random.uniform(ks[7], (n,)))
    ratio_a = 1.15 + 0.3 * jax.random.uniform(ks[8], (n,))
    ratio_b = -0.05 - 0.08 * jax.random.uniform(ks[9], (n,))
    return {"capacity": capacity, "flex_share": flex_share,
            "base_if": base_if, "diurnal_amp": diurnal_amp,
            "peak_hour": peak_hour, "weekly_amp": weekly_amp,
            "noise": noise, "arr_level": arr_level,
            "ratio_a": ratio_a, "ratio_b": ratio_b}


def _cluster_truth(key, cfg: FleetConfig):
    return cluster_truth(key, cfg.n_clusters)


def _sample_inflexible(key, truth, day):
    """Actual inflexible hourly usage for one day. (n, 24)."""
    hours = jnp.arange(24, dtype=f32)
    d = jnp.minimum(jnp.abs(hours[None] - truth["peak_hour"][:, None]),
                    24 - jnp.abs(hours[None] - truth["peak_hour"][:, None]))
    diurnal = 1.0 + truth["diurnal_amp"][:, None] * jnp.exp(
        -0.5 * (d / 4.0) ** 2)
    weekly = 1.0 + truth["weekly_amp"][:, None] * jnp.cos(
        2 * jnp.pi * (day % 7) / 7.0)
    eps = 1.0 + truth["noise"][:, None] * jax.random.normal(
        key, (truth["base_if"].shape[0], 24))
    return truth["base_if"][:, None] * diurnal * weekly * eps


def _sample_arrivals(key, truth, day):
    """Flexible CPU-hour arrivals per hour. (n, 24)."""
    hours = jnp.arange(24, dtype=f32)
    prof = 0.6 + 0.8 * jnp.exp(-0.5 * ((hours[None] - 11.0) / 5.0) ** 2)
    weekly = 1.0 + 0.5 * truth["weekly_amp"][:, None] * jnp.cos(
        2 * jnp.pi * (day % 7) / 7.0)
    eps = 1.0 + 2.5 * truth["noise"][:, None] * jax.random.normal(
        key, (truth["arr_level"].shape[0], 24))
    return jnp.clip(truth["arr_level"][:, None] * prof * weekly * eps / 24.0
                    * 24.0 / prof.sum() * 24.0, 0.0, None)


def _true_ratio(truth, usage):
    return jnp.clip(truth["ratio_a"][:, None]
                    + truth["ratio_b"][:, None]
                    * jnp.log(jnp.clip(usage, 1e-6, None)), 1.05, 3.0)


def init_fleet(cfg: FleetConfig) -> FleetState:
    key = jax.random.PRNGKey(cfg.seed)
    ks = jax.random.split(key, 8)
    n = cfg.n_clusters
    truth = _cluster_truth(ks[0], cfg)
    zones = carbon.default_zones(cfg.n_zones)
    zone_of_campus = np.arange(cfg.n_campuses) % cfg.n_zones
    campus = jnp.asarray(np.arange(n) % cfg.n_campuses, jnp.int32)
    # PD power truth
    npd = n * cfg.pds_per_cluster
    pd_truth = power.PDTruth(
        idle_kw=60.0 + 40.0 * jax.random.uniform(ks[1], (npd,)),
        slope_kw=250.0 + 150.0 * jax.random.uniform(ks[2], (npd,)),
        curve=0.8 + 0.5 * jax.random.uniform(ks[3], (npd,)),
    )
    lam = jax.nn.softmax(jax.random.normal(ks[4], (n, cfg.pds_per_cluster)),
                         axis=1)
    # carbon history
    zone_hist = jnp.stack([carbon.simulate_zone(jax.random.fold_in(ks[5], i),
                                                z, HIST_DAYS)
                           for i, z in enumerate(zones)])
    state = FleetState(
        cfg=cfg, day=HIST_DAYS,
        capacity=truth["capacity"], campus=campus,
        zone_of_campus=zone_of_campus,
        campus_limit=jnp.full((cfg.n_campuses,), 0.0),
        u_pow_cap=truth["capacity"] * 0.95,
        truth=truth, pd_truth=pd_truth, lam=lam,
        hist_uif=jnp.zeros((n, HIST_DAYS, 24)),
        hist_flex_daily=jnp.zeros((n, HIST_DAYS)),
        hist_res_daily=jnp.zeros((n, HIST_DAYS)),
        hist_usage=jnp.zeros((n, HIST_DAYS, 24)),
        hist_res=jnp.zeros((n, HIST_DAYS, 24)),
        hist_tr_pred=jnp.zeros((n, HIST_DAYS)),
        hist_uif_pred=jnp.zeros((n, HIST_DAYS, 24)),
        carbon_hist=zone_hist,
        queue=jnp.zeros((n,)),
        slo_state=slo.init_state(n),
        shaping_allowed=jnp.ones((n,), bool),
        zones=zones,
    )
    # burn-in: run HIST_DAYS unshaped days to fill history
    for d in range(HIST_DAYS):
        state = _observe_day(state, d, shaped=False)
    # backfill prediction history with actuals (zero-error prior); the
    # trailing-error quantiles become honest within days of operation
    state.hist_tr_pred = state.hist_res_daily
    state.hist_uif_pred = state.hist_uif
    # campus limits: 95% of observed campus peak (forces peak shaving)
    camp_pow = np.zeros((cfg.n_campuses,))
    power_fn, _, _ = make_power_fn(state)
    upow = np.asarray(jax.vmap(power_fn, in_axes=1, out_axes=1)(
        state.hist_usage[:, -7:].reshape(n, -1)))
    peak = upow.max(axis=1)
    for c in range(cfg.n_campuses):
        camp_pow[c] = peak[np.asarray(campus) == c].sum() * 0.97
    state.campus_limit = jnp.asarray(camp_pow, f32)
    return state


def power_model_from_history(hist_usage, lam, capacity, pd_truth, key):
    """Pure core of make_power_fn: fit PD piecewise power models on recent
    cluster usage history and return cluster power/slope closures.

    hist_usage: (n, hist, 24); lam: (n, pds); capacity: (n,);
    pd_truth: power.PDTruth with (n*pds,) fields. jit/vmap-safe.
    """
    n, npd = lam.shape
    u_cl = hist_usage[:, -28:].reshape(n, -1)                # (n, t)
    u_pd = (lam[..., None] * u_cl[:, None, :]).reshape(n * npd, -1)
    u_norm = u_pd / jnp.clip(
        capacity[:, None, None].repeat(npd, 1).reshape(n * npd, 1),
        1e-6, None)
    p_pd = power.simulate_pd_power(key, pd_truth, u_norm)
    coef, breaks = power.fit_pd_models(u_norm, p_pd)
    # materialization point: keeps the fitted model's numerics independent
    # of how downstream consumers fuse (bitwise batched/sequential parity)
    coef, breaks = jax.lax.optimization_barrier((coef, breaks))

    cap_pd = capacity[:, None].repeat(npd, 1).reshape(-1)

    def cluster_power_fn(u_cluster):                         # (n,) -> (n,)
        u_pd_now = (lam * u_cluster[:, None]).reshape(-1)
        u_n = u_pd_now / jnp.clip(cap_pd, 1e-6, None)
        p = jax.vmap(power.pd_power)(coef, breaks, u_n[:, None])[:, 0]
        return p.reshape(n, npd).sum(axis=1)

    def cluster_slope_fn(u_cluster):
        u_pd_now = (lam * u_cluster[:, None]).reshape(-1)
        u_n = u_pd_now / jnp.clip(cap_pd, 1e-6, None)
        s = jax.vmap(power.pd_slope)(coef, breaks, u_n[:, None])[:, 0]
        s = s / jnp.clip(cap_pd, 1e-6, None)       # d kW / d cluster-CPU
        return (s.reshape(n, npd) * lam).sum(axis=1)

    return cluster_power_fn, cluster_slope_fn, (coef, breaks)


def make_power_fn(state: FleetState):
    """Cluster power from PD piecewise models fit on recent history."""
    return power_model_from_history(state.hist_usage, state.lam,
                                    state.truth["capacity"], state.pd_truth,
                                    jax.random.PRNGKey(state.day))


def day_forecasts_arrays(hist_uif, hist_flex_daily, hist_res_daily,
                         hist_usage, hist_res, hist_tr_pred, hist_uif_pred,
                         day, gamma):
    """Pure core of day_forecasts: next-day forecasting pipeline from
    rolling history arrays. All (n, hist[, 24]); day/gamma may be traced."""
    n = hist_uif.shape[0]
    dow = jnp.asarray(day % 7)
    uif_pred = jax.vmap(lambda h: forecast.forecast_inflexible(h, dow))(
        hist_uif)
    tuf_pred = jax.vmap(lambda d: forecast.forecast_daily_total(d, dow))(
        hist_flex_daily)
    tr_pred = jax.vmap(lambda d: forecast.forecast_daily_total(d, dow))(
        hist_res_daily)
    ra, rb = jax.vmap(forecast.fit_ratio_model)(
        hist_usage[:, -28:].reshape(n, -1),
        hist_res[:, -28:].reshape(n, -1))
    eps97 = jax.vmap(lambda p, a: forecast.relative_error_quantile(
        p[-90:], a[-90:], 0.97))(hist_tr_pred, hist_res_daily)
    theta = forecast.theta_requirement(tr_pred, eps97)
    alpha = jax.vmap(forecast.alpha_inflation)(theta, uif_pred, tuf_pred,
                                               ra, rb)
    # (1-gamma) hourly inflexible quantile from trailing prediction errors
    epsq = jax.vmap(lambda p, a: forecast.relative_error_quantile(
        p[-28:].reshape(-1), a[-28:].reshape(-1), 1 - gamma))(
        hist_uif_pred, hist_uif)
    uif_q = uif_pred * (1.0 + jnp.clip(epsq, 0.0, 1.0)[:, None])
    return {"uif": uif_pred, "tuf": tuf_pred, "tr": tr_pred,
            "ratio_a": ra, "ratio_b": rb, "theta": theta, "alpha": alpha,
            "uif_q": uif_q}


def day_forecasts(state: FleetState):
    """Run the forecasting pipeline for the next day (vmapped)."""
    return day_forecasts_arrays(
        state.hist_uif, state.hist_flex_daily, state.hist_res_daily,
        state.hist_usage, state.hist_res, state.hist_tr_pred,
        state.hist_uif_pred, state.day, state.cfg.gamma)


def carbon_forecast_next(state: FleetState, day: int):
    """Actual + day-ahead forecast intensity per cluster for the day."""
    key = jax.random.PRNGKey(1000 + day)
    actuals, forecasts = [], []
    for i, z in enumerate(state.zones):
        act = carbon.simulate_zone(jax.random.fold_in(key, i), z, 1)[0]
        fc = carbon.forecast_day_ahead(jax.random.fold_in(key, 100 + i),
                                       state.carbon_hist[i], act,
                                       z.weather_vol * 0.15)
        actuals.append(act)
        forecasts.append(fc)
    actual_z = jnp.stack(actuals)         # (zones, 24)
    fc_z = jnp.stack(forecasts)
    zmap = jnp.asarray(state.zone_of_campus[np.asarray(state.campus)],
                       jnp.int32)
    return actual_z, fc_z, actual_z[zmap], fc_z[zmap]


def build_problem_arrays(fc, eta_fc, power_fn, slope_fn, queue, u_pow_cap,
                         capacity, campus, campus_limit, lambda_e, lambda_p
                         ) -> vcc.VCCProblem:
    """Pure core of build_problem: assemble the fleetwide VCC problem from
    forecast dict + carbon forecast + structural arrays."""
    # risk-aware daily flexible budget (eq. 3) + carried-over queue
    tau = fc["alpha"] * fc["tuf"] + queue
    u_nom = fc["uif"] + tau[:, None] / 24.0
    pow_nom = jax.vmap(power_fn, in_axes=1, out_axes=1)(u_nom)
    pi = jax.vmap(slope_fn, in_axes=1, out_axes=1)(u_nom)
    ratio = forecast.ratio_at(fc["ratio_a"][:, None], fc["ratio_b"][:, None],
                              u_nom)
    return vcc.VCCProblem(
        eta=eta_fc, u_if=fc["uif"], u_if_q=fc["uif_q"], tau=tau,
        pow_nom=pow_nom, pi=pi, u_pow_cap=u_pow_cap,
        capacity=capacity, ratio=ratio, campus=campus,
        campus_limit=campus_limit, lambda_e=lambda_e, lambda_p=lambda_p)


def build_problem(state: FleetState, fc, eta_fc, power_fn, slope_fn
                  ) -> vcc.VCCProblem:
    return build_problem_arrays(fc, eta_fc, power_fn, slope_fn, state.queue,
                                state.u_pow_cap, state.capacity,
                                state.campus, state.campus_limit,
                                state.cfg.lambda_e, state.cfg.lambda_p)


def _observe_day(state: FleetState, day: int, shaped: bool,
                 vcc_curve=None, treat_mask=None, collect=False):
    """Run one actual day (optionally VCC-shaped) and roll histories."""
    cfg = state.cfg
    n = cfg.n_clusters
    key = jax.random.PRNGKey(10_000 + day)
    k1, k2 = jax.random.split(key)
    u_if = _sample_inflexible(k1, state.truth, day)
    arrivals = _sample_arrivals(k2, state.truth, day)
    usage_unshaped = u_if + arrivals            # rough for ratio sampling
    ratio_true = _true_ratio(state.truth, usage_unshaped)
    # burn-in uses a cheap linear power proxy (power is telemetry-only here)
    power_fn, slope_fn, _ = make_power_fn(state) if day >= HIST_DAYS else \
        (lambda u: 100.0 + 300.0 * u, lambda u: jnp.full_like(u, 300.0),
         None)
    if vcc_curve is None:
        vcc_curve = jnp.broadcast_to(state.capacity[:, None] * 10.0,
                                     (n, 24))
    if treat_mask is not None:
        vcc_curve = jnp.where(treat_mask[:, None], vcc_curve,
                              state.capacity[:, None] * 10.0)
    # actual carbon for the day
    keyz = jax.random.PRNGKey(1000 + day)
    actual_z = jnp.stack([
        carbon.simulate_zone(jax.random.fold_in(keyz, i), z, 1)[0]
        for i, z in enumerate(state.zones)])
    zmap = jnp.asarray(state.zone_of_campus[np.asarray(state.campus)],
                       jnp.int32)
    intensity = actual_z[zmap]
    res = admission.run_day(vcc_curve, u_if, arrivals, ratio_true,
                            state.capacity, state.queue, power_fn,
                            intensity)
    # roll histories
    def roll(hist, new):
        return jnp.concatenate([hist[:, 1:], new[:, None]], axis=1)

    state.hist_uif = jnp.concatenate(
        [state.hist_uif[:, 1:], u_if[:, None]], axis=1)
    state.hist_flex_daily = roll(state.hist_flex_daily, res.served)
    state.hist_res_daily = roll(state.hist_res_daily,
                                res.reservations.sum(axis=1))
    state.hist_usage = jnp.concatenate(
        [state.hist_usage[:, 1:], res.usage_total[:, None]], axis=1)
    state.hist_res = jnp.concatenate(
        [state.hist_res[:, 1:], res.reservations[:, None]], axis=1)
    state.carbon_hist = jnp.concatenate(
        [state.carbon_hist[:, 1:], actual_z[:, None]], axis=1)
    state.queue = res.queue_end
    state.day = day + 1
    if collect:
        return state, res, intensity
    return state


def day_cycle(state: FleetState, record: Optional[dict] = None
              ) -> FleetState:
    """One full CICS day: forecast -> optimize -> shape -> observe."""
    day = state.day
    power_fn, slope_fn, _ = make_power_fn(state)
    fc = day_forecasts(state)
    _, _, eta_act, eta_fc = carbon_forecast_next(state, day)
    prob = build_problem(state, fc, eta_fc, power_fn, slope_fn)
    sol = vcc.solve_vcc(prob)
    vcc_curve = jnp.where((state.shaping_allowed & sol.shaped)[:, None],
                          sol.vcc, state.capacity[:, None] * 10.0)
    # record predictions for trailing-error quantiles
    state.hist_tr_pred = jnp.concatenate(
        [state.hist_tr_pred[:, 1:], fc["tr"][:, None]], axis=1)
    state.hist_uif_pred = jnp.concatenate(
        [state.hist_uif_pred[:, 1:], fc["uif"][:, None]], axis=1)
    state, res, intensity = _observe_day(state, day, True, vcc_curve,
                                         collect=True)
    new_slo, allowed = slo.update(state.slo_state, state.cfg.slo,
                                  res.reservations.sum(axis=1),
                                  vcc_curve.sum(axis=1), res.unmet)
    state.slo_state = new_slo
    state.shaping_allowed = allowed
    if record is not None:
        record.update(dict(fc=fc, sol=sol, vcc=vcc_curve, result=res,
                           intensity=intensity, problem=prob))
    return state
