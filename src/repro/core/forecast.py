"""Day-ahead load forecasting (paper §III-B1).

Per cluster, the pipeline forecasts:
  (i)   hourly inflexible CPU usage  U_IF(h)      [ (days,24) history ]
  (ii)  daily flexible compute usage T_UF(d)      [ (days,) ]
  (iii) daily total reservations     T_R(d)       [ (days,) ]
  (iv)  reservations-to-usage ratio  R(h) >= 1    [ linear in log usage ]

Method (paper): two-step. First a weekly forecast = EWMA weekly mean
(half-life ~0.5 weeks) x EWMA intra-week hourly/daily factors (half-life ~4
weeks); then a linear previous-day deviation corrector. EWMA half-lives are
tunable (the paper selects them by out-of-sample MAPE exploration —
``calibrate_half_lives``). Risk terms: trailing relative-error quantiles give
the 97%-ile capacity requirement Theta (eq. 2) and the (1-gamma) inflexible
quantile for power capping; eq. (3) yields the alpha inflation factor.

Everything is vmap-friendly: functions take one cluster's history; fleet.py
vmaps them across clusters.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


def register_barrier_batching():
    """jax<=0.4 ships no vmap rule for optimization_barrier (newer jax
    does). The rule is the identity on batch dims: barrier each operand,
    keep its batch axis. Lives here (the lowest module that emits
    barriers — ``ewma_update`` pins its products); ``stages`` re-uses it."""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as _lax
        prim = _lax.optimization_barrier_p
    except (ImportError, AttributeError):    # pragma: no cover
        return
    if prim in batching.primitive_batchers:
        return

    def rule(args, dims):
        return prim.bind(*args), dims

    batching.primitive_batchers[prim] = rule


register_barrier_batching()


def ewma_alpha(half_life) -> jnp.ndarray:
    """One-step EWMA weight for a given half-life, measured in UPDATE
    STEPS (weeks for the weekly rescan, days for the streaming carry).
    Shared by the batch ``ewma`` scan below and the O(1) incremental
    ``core.stats`` estimators — one expression, so the two paths apply
    bitwise-identical recursions."""
    return 1.0 - jnp.exp(jnp.log(0.5) / jnp.maximum(half_life, 1e-3))


def ewma_update(level: jnp.ndarray, x: jnp.ndarray, alpha) -> jnp.ndarray:
    """One EWMA step: the exact expression the ``ewma`` scan body
    applies. ``core.stats`` carries this across days; a COMPILED
    single-step chain (the streaming day step always runs jitted)
    reproduces the batch scan bitwise — XLA contracts the mul+add into
    the same fma in both compiled forms (property-tested; fully-eager
    per-op dispatch rounds the products separately and may differ in the
    last ulp, which is the repo-wide eager-vs-compiled caveat)."""
    return alpha * x + (1 - alpha) * level


def ewma(x: jnp.ndarray, half_life: float) -> jnp.ndarray:
    """EWMA over the leading axis (oldest first); returns the final level."""
    alpha = ewma_alpha(half_life)

    def step(level, xi):
        return ewma_update(level, xi, alpha), None

    level0 = x[0]
    level, _ = jax.lax.scan(step, level0, x[1:])
    return level


def weekly_mean_forecast(daily: jnp.ndarray, half_life_weeks: float = 0.5
                         ) -> jnp.ndarray:
    """daily: (days,) -> forecast of next week's mean (scalar).
    Trailing full weeks only."""
    d = daily.shape[0]
    nw = d // 7
    weekly = daily[d - nw * 7:].reshape(nw, 7).mean(axis=1)
    return ewma(weekly, half_life_weeks)


def hourly_factor_forecast(hourly: jnp.ndarray, half_life_weeks: float = 4.0
                           ) -> jnp.ndarray:
    """hourly: (days, 24) -> per hour-of-week factors folded to (7,24)."""
    d = hourly.shape[0]
    nw = d // 7
    h = hourly[d - nw * 7:].reshape(nw, 7, 24)
    wmean = jnp.clip(h.mean(axis=(1, 2), keepdims=True), 1e-9, None)
    factors = h / wmean                      # (nw, 7, 24)
    return ewma(factors, half_life_weeks)    # (7, 24)


def daily_factor_forecast(daily: jnp.ndarray, half_life_weeks: float = 4.0
                          ) -> jnp.ndarray:
    """daily: (days,) -> day-of-week factors (7,)."""
    d = daily.shape[0]
    nw = d // 7
    dd = daily[d - nw * 7:].reshape(nw, 7)
    wmean = jnp.clip(dd.mean(axis=1, keepdims=True), 1e-9, None)
    return ewma(dd / wmean, half_life_weeks)


def deviation_coef(actual: jnp.ndarray, weekly_pred: jnp.ndarray
                   ) -> jnp.ndarray:
    """Linear model: next-day deviation ~ coef * previous-day deviation."""
    dev = actual - weekly_pred
    x, y = dev[:-1], dev[1:]
    num = jnp.sum(x * y)
    den = jnp.clip(jnp.sum(x * x), 1e-9, None)
    return jnp.clip(num / den, -1.0, 1.0)


# fold columns of the trailing 8 days (k = 8..1 days before the forecast
# day): column (-k) % 7 of the week fold — see POS_NEXT/POS_PREV below
POS8 = tuple(int((7 - k) % 7) for k in range(8, 0, -1))
POS_NEXT, POS_PREV = 0, 6


def forecast_inflexible(hourly: jnp.ndarray, dow_next: jnp.ndarray,
                        hl_mean: float = 0.5, hl_factor: float = 4.0
                        ) -> jnp.ndarray:
    """Next-day hourly inflexible usage forecast. hourly: (days,24);
    returns (24,).

    The week fold is indexed POSITIONALLY: the trailing whole-week
    window ends yesterday, so fold column 0 always holds the forecast
    day's day-of-week and column 6 yesterday's — for EVERY forecast day,
    not just when the window phase happens to align. (The old
    ``factors[dow_next]`` indexing silently rotated through the week as
    the window slid: 6 days out of 7 it applied a neighboring dow's
    pattern.) ``dow_next`` is kept for API compatibility; the phase is
    fully encoded by the window itself."""
    del dow_next
    daily = hourly.mean(axis=1)
    wmean = weekly_mean_forecast(daily, hl_mean)
    factors = hourly_factor_forecast(hourly, hl_factor)      # (7,24)
    weekly_fc_next = wmean * factors[POS_NEXT]
    # previous-day deviation correction (same-hour deviations). The coef
    # is fit on deviations from the dow-FACTORED weekly predictions — a
    # constant level here would fold the intra-week pattern into the
    # "deviations" and bias the correction (regression-tested).
    prev_pred = wmean * factors[POS_PREV]
    dev_prev = hourly[-1] - prev_pred
    coef = deviation_coef(hourly[-8:].mean(axis=1),
                          wmean * factors[jnp.asarray(POS8)].mean(axis=-1))
    return jnp.clip(weekly_fc_next + coef * dev_prev, 0.0, None)


def forecast_daily_total(daily: jnp.ndarray, dow_next: jnp.ndarray,
                         hl_mean: float = 0.5, hl_factor: float = 4.0
                         ) -> jnp.ndarray:
    """Next-day total (flexible usage or reservations). daily: (days,).
    Positional fold indexing, same as ``forecast_inflexible``."""
    del dow_next
    wmean = weekly_mean_forecast(daily, hl_mean)         # daily level
    factors = daily_factor_forecast(daily, hl_factor)    # (7,) dow factors
    pred_next = wmean * factors[POS_NEXT]
    prev_pred = wmean * factors[POS_PREV]
    # corrector fit against the dow-factored weekly predictions (a
    # constant level here leaks the weekly pattern into the deviations)
    coef = deviation_coef(daily[-8:], wmean * factors[jnp.asarray(POS8)])
    return jnp.clip(pred_next + coef * (daily[-1] - prev_pred), 0.0, None)


def fit_ratio_model(usage: jnp.ndarray, reservations: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """R = a + b * log(usage), fit by least squares on hourly samples.
    usage, reservations: (t,) flattened hourly totals."""
    r = reservations / jnp.clip(usage, 1e-9, None)
    x = jnp.log(jnp.clip(usage, 1e-9, None))
    xm, rm = x.mean(), r.mean()
    b = jnp.sum((x - xm) * (r - rm)) / jnp.clip(jnp.sum((x - xm) ** 2),
                                                1e-9, None)
    a = rm - b * xm
    return a, b


def ratio_at(a, b, usage):
    return jnp.clip(a + b * jnp.log(jnp.clip(usage, 1e-9, None)), 1.0, 10.0)


def relative_error_quantile(pred_hist: jnp.ndarray, actual_hist: jnp.ndarray,
                            q: float) -> jnp.ndarray:
    """q-quantile of trailing relative errors (eq. 2's epsilon term)."""
    eps = (actual_hist - pred_hist) / jnp.clip(jnp.abs(pred_hist), 1e-9, None)
    return jnp.quantile(eps, q)


def theta_requirement(tr_pred_next: jnp.ndarray, eps_q97: jnp.ndarray
                      ) -> jnp.ndarray:
    """Theta^(c)(d) = T_R-hat * (1 + eps_.97)  (paper eq. 2)."""
    return tr_pred_next * (1.0 + jnp.clip(eps_q97, 0.0, 2.0))


def alpha_inflation(theta: jnp.ndarray, uif_pred: jnp.ndarray,
                    tuf_pred: jnp.ndarray, ratio_a, ratio_b) -> jnp.ndarray:
    """Solve eq. (3) for alpha: sum_h (U_IF(h) + a*T_UF/24) * R(h) = Theta,
    with R evaluated at the nominal usage."""
    u_nom = uif_pred + tuf_pred / 24.0
    r = ratio_at(ratio_a, ratio_b, u_nom)
    denom = jnp.clip(jnp.sum(tuf_pred / 24.0 * r), 1e-9, None)
    alpha = (theta - jnp.sum(uif_pred * r)) / denom
    return jnp.clip(alpha, 0.5, 4.0)


def _walk_forward_mape(hourly: jnp.ndarray, hm, hf) -> jnp.ndarray:
    """Mean walk-forward MAPE of ``forecast_inflexible`` at half-lives
    (hm, hf) on the trailing 14 days (two 7-day-apart holdouts). hm/hf
    may be traced — the half-life only enters through ``ewma_alpha``."""
    errs = []
    for back in range(14, 0, -7):
        hist = hourly[:-back]
        dow = jnp.asarray((hourly.shape[0] - back) % 7)
        pred = forecast_inflexible(hist, dow, hm, hf)
        act = hourly[-back]
        errs.append(jnp.mean(jnp.abs(pred - act)
                             / jnp.clip(act, 1e-6, None)))
    return jnp.stack(errs).mean()


def calibrate_half_lives(hourly: jnp.ndarray,
                         grid=(0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
                         ) -> Tuple[float, float]:
    """Paper: 'EWMA parameters are selected by exploration over a given
    range, so that out-of-sample MAPE is minimized.' Walk-forward eval on
    the trailing 14 days.

    The whole grid x grid exploration is ONE vmapped+jitted evaluation
    (half-lives are data, not Python constants — no re-trace per combo);
    ``argmin`` over the row-major error surface selects the same
    (first-best) pair as the legacy Python loop
    (``calibrate_half_lives_loop``, kept as the parity reference)."""
    g = len(grid)
    garr = jnp.asarray(grid, f32)
    hms = jnp.repeat(garr, g)            # row-major: hm outer, hf inner
    hfs = jnp.tile(garr, g)
    errs = jax.jit(jax.vmap(_walk_forward_mape, in_axes=(None, 0, 0)))(
        hourly, hms, hfs)
    i = int(jnp.argmin(errs))            # first minimum == loop's `<`
    return float(grid[i // g]), float(grid[i % g])


def calibrate_half_lives_loop(hourly: jnp.ndarray,
                              grid=(0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
                              ) -> Tuple[float, float]:
    """Legacy per-combo Python loop (re-traces the forecast per pair);
    kept as the reference the vectorized selection is tested against."""
    best = (0.5, 4.0)
    best_err = jnp.inf
    for hm in grid:
        for hf in grid:
            err = _walk_forward_mape(hourly, hm, hf)
            if err < best_err:
                best_err, best = err, (hm, hf)
    return best
