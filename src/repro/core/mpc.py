"""Intra-day MPC recourse: the hourly closed loop over the day-ahead VCC.

The paper's pipeline commits a Virtual Capacity Curve once per day
(§III), so when actuals diverge from the day-ahead forecast the plan is
stale for up to 23 hours — exactly the regime where "Let's Wait Awhile"
shows shifting gains collapse. This module closes the loop at hour grain:

  each hour h:
    1. enforce the CURRENT plan's VCC for hour h through the same
       ``admission.admission_tick`` the open loop scans (shared code —
       the controller cannot fork from the open-loop semantics),
    2. absorb the realized hour into the ``stats.HourAccum`` hour-grain
       predictor accumulator (finalized into the streaming
       ``PredictorState`` at day close),
    3. nowcast the remaining hours — persistence-decay corrections of
       the intensity / inflexible forecasts from the latest observed
       ratio, and a demand-surprise term that grows the flexible budget
       tau when realized arrivals outrun the forecast's pro-rata share,
    4. warm-start a re-solve of the REMAINING hours' deviations
       (``vcc.solve_vcc_suffix``: elapsed hours pinned at realized
       values, conservation tightened to the suffix, outer 2 x inner 8 =
       16 PGD steps vs the day solve's 1600),
    5. accept the revised plan per cluster only when a staleness TRIGGER
       fires — the same signals the telemetry layer gauges (elapsed-hour
       ``uif_mape``, intensity forecast deviation, demand surprise vs
       the tau budget) — and record trigger/depth diagnostics.

Everything is elementwise ops + ``lax.scan`` + ordered ``hour_sum``
reductions, so the closed loop keeps the engine's bitwise
batched==sequential parity. The ``StageConfig.mpc=False`` day step never
calls into this module (Python-level flag), preserving the byte-identical
HLO collapse contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import admission, stats, vcc
from repro.core.admission import hour_sum

f32 = jnp.float32

# staleness-trigger thresholds (recourse accepts a re-solved suffix only
# when the day-ahead plan is measurably stale; under nominal forecast
# noise the loop stays open and the realized day matches the committed
# plan's intent)
MAPE_TRIGGER = 0.08      # elapsed-hour U_IF MAPE above typical noise
ETA_TRIGGER = 0.20       # |realized/forecast intensity - 1| last hour
SURGE_TRIGGER = 0.05     # demand surprise as a fraction of tau
# persistence-decay of the last observed forecast-error ratio applied to
# the remaining hours (h hours ahead decays as DECAY**h)
ETA_DECAY = 0.7
UIF_DECAY = 0.5


class MPCDiag(NamedTuple):
    """Per-cluster recourse diagnostics for the telemetry record."""
    recourse_frac: jnp.ndarray    # (n,) fraction of hours re-planned
    recourse_depth: jnp.ndarray   # (n,) mean |delta change| when re-planned


def gated_curve(p: vcc.VCCProblem, delta, tau, gate, cap_day):
    """The hourly reservation curve the scheduler enforces for plan
    ``(delta, tau)``: the ``solve_vcc`` curve formula under the SLO gate
    (paused / infeasible clusters see VCC = 10x capacity = unshaped)."""
    vcc_shaped = (p.u_if + (1.0 + delta) * tau[:, None] / 24.0) * p.ratio
    v = jnp.minimum(vcc_shaped, p.capacity[:, None])
    return jnp.where(gate[:, None], v, cap_day[:, None] * 10.0)


def mpc_day(prob: vcc.VCCProblem, sol: vcc.VCCSolution, tuf_fc, gate,
            cap_day, u_if, arrivals, ratio_true, queue0, power_fn,
            intensity, *, allowance_frac: float = 0.25,
            inner_iters: int = 8, outer_iters: int = 2,
            use_pallas: Optional[bool] = None, interpret: bool = False
            ) -> Tuple[admission.DayResult, jnp.ndarray, stats.HourAccum,
                       MPCDiag]:
    """Run one closed-loop day: 24 admission ticks with hourly warm-started
    suffix re-solves of the remaining VCC.

    ``prob``/``sol``: the day-ahead problem and its solution; ``tuf_fc``:
    the (n,) day-ahead flexible-total forecast (demand-surprise
    reference); ``gate``: (n,) bool = shaping_allowed & sol.shaped (fixed
    for the day — paused/infeasible clusters stay open-loop); ``u_if`` /
    ``arrivals`` / ``ratio_true`` / ``intensity``: (n, 24) actuals.

    Returns (DayResult, enforced_vcc (n, 24), HourAccum, MPCDiag). The
    enforced curve is the hour-by-hour curve admission actually saw —
    that is what the SLO crowding detector and the binding-fraction
    telemetry must be measured against, not the 00:00 plan.
    """
    n = prob.tau.shape[0]
    tau0 = prob.tau
    hours_f = jnp.arange(24, dtype=f32)

    carry0 = dict(
        queue=queue0,
        delta=sol.delta,
        tau=tau0,
        mu=sol.mu,
        acc=stats.hour_accum_init(n),
        vcc_real=jnp.zeros((n, 24), f32),
        arr_sofar=jnp.zeros((n,), f32),
        mape_sum=jnp.zeros((n,), f32),
        trig_hours=jnp.zeros((n,), f32),
        depth_sum=jnp.zeros((n,), f32),
    )
    xs = (jnp.arange(24), u_if.T, arrivals.T, ratio_true.T, intensity.T)

    def hour_step(c, x):
        h, uif_h, arr_h, r_h, eta_h = x
        # 1. enforce the current plan's curve for this hour
        curve = gated_curve(prob, c["delta"], c["tau"], gate, cap_day)
        vcc_h = curve[:, h]
        queue, use_flex_h = admission.admission_tick(
            c["queue"], vcc_h, uif_h, arr_h, r_h, cap_day)
        # 2. hour-grain predictor advancement
        acc = stats.hour_update(c["acc"], h, uif_h, use_flex_h, r_h)
        vcc_real = c["vcc_real"].at[:, h].set(vcc_h)
        # 3. staleness signals (the telemetry gauges, computed in-loop)
        fc_uif_h = prob.u_if[:, h]
        fc_eta_h = prob.eta[:, h]
        elapsed = (h + 1).astype(f32)
        arr_sofar = c["arr_sofar"] + arr_h
        mape_sum = c["mape_sum"] + jnp.abs(fc_uif_h - uif_h) \
            / jnp.clip(jnp.abs(uif_h), 1e-6, None)
        mape_el = mape_sum / elapsed
        r_eta = eta_h / jnp.clip(fc_eta_h, 1e-6, None)
        r_uif = uif_h / jnp.clip(fc_uif_h, 1e-6, None)
        q_extra = jnp.clip(arr_sofar - elapsed / 24.0 * tuf_fc, 0.0, None)
        trigger = (mape_el > MAPE_TRIGGER) \
            | (jnp.abs(r_eta - 1.0) > ETA_TRIGGER) \
            | (q_extra > SURGE_TRIGGER * jnp.clip(tau0, 1e-6, None))
        # 4. nowcast the remaining hours: persistence-decay corrections +
        #    the demand-surprise budget growth
        ahead = jnp.clip(hours_f[None, :] - elapsed, 0.0, None)
        rem = hours_f[None, :] >= elapsed          # (1, 24) hours > h
        eta_corr = 1.0 + (jnp.clip(r_eta, 0.25, 4.0) - 1.0)[:, None] \
            * ETA_DECAY ** ahead
        uif_corr = 1.0 + (jnp.clip(r_uif, 0.5, 2.0) - 1.0)[:, None] \
            * UIF_DECAY ** ahead
        p_now = dataclasses.replace(
            prob,
            eta=jnp.where(rem, prob.eta * eta_corr, prob.eta),
            u_if=jnp.where(rem, prob.u_if * uif_corr, prob.u_if),
            u_if_q=jnp.where(rem, prob.u_if_q * uif_corr, prob.u_if_q),
            tau=tau0 + q_extra)
        tau_new = p_now.tau
        # 5. warm start: elapsed hours pinned at realized deviations (in
        #    the NEW budget's units), remaining hours keep the planned
        #    USAGE (1+delta)*tau/24 re-expressed at the new budget
        tau24_new = jnp.clip(tau_new[:, None] / 24.0, 1e-9, None)
        pinned = acc.use_flex / tau24_new - 1.0
        scale = (c["tau"] / jnp.clip(tau_new, 1e-9, None))[:, None]
        delta_warm = jnp.where(rem, (1.0 + c["delta"]) * scale - 1.0,
                               pinned)
        sol_s = vcc.solve_vcc_suffix(
            p_now, delta_warm, c["mu"], h + 1, inner_iters=inner_iters,
            outer_iters=outer_iters, use_pallas=use_pallas,
            interpret=interpret)
        accept = gate & trigger & sol_s.shaped
        delta_next = jnp.where(accept[:, None], sol_s.delta, c["delta"])
        tau_next = jnp.where(accept, tau_new, c["tau"])
        # 6. recourse depth: mean |delta change| over the remaining hours
        rem_n = jnp.clip(hour_sum(rem.astype(f32)), 1.0, None)
        depth = hour_sum(jnp.abs(delta_next - c["delta"])
                         * rem.astype(f32)) / rem_n
        return dict(
            queue=queue, delta=delta_next, tau=tau_next, mu=sol_s.mu,
            acc=acc, vcc_real=vcc_real, arr_sofar=arr_sofar,
            mape_sum=mape_sum,
            trig_hours=c["trig_hours"] + accept.astype(f32),
            depth_sum=c["depth_sum"] + depth), None

    c, _ = jax.lax.scan(hour_step, carry0, xs)
    res = admission.finalize_day(
        c["acc"].use_flex, c["queue"], u_if, arrivals, ratio_true, queue0,
        power_fn, intensity, allowance_frac)
    diag = MPCDiag(
        recourse_frac=c["trig_hours"] / 24.0,
        recourse_depth=c["depth_sum"] / jnp.clip(c["trig_hours"], 1.0,
                                                 None))
    return res, c["vcc_real"], c["acc"], diag
