"""Power-domain power models (paper §III-A, following ref [20]).

A PD's power is a piecewise-linear function of its CPU usage; the paper
reports daily MAPE < 5% for >95% of PDs, and uses the local slope
``pi^(PD)(u)`` to map CPU deltas to power deltas. Cluster-level slope is the
lambda-weighted sum over its PDs (PD usage fractions are near-constant).

Models are refit daily, vmapped across every PD in the fleet.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32
N_BREAKS = 3            # interior breakpoints -> 4 linear segments


@dataclass(frozen=True)
class PDTruth:
    """Ground-truth (simulator) PD power curve parameters."""
    idle_kw: jnp.ndarray        # (pds,)
    slope_kw: jnp.ndarray       # (pds,) average dynamic slope
    curve: jnp.ndarray          # (pds,) curvature in [0.7, 1.3] (u^curve)


def simulate_pd_power(key, truth: PDTruth, cpu: jnp.ndarray,
                      noise: float = 0.01) -> jnp.ndarray:
    """True PD power for CPU usage series. cpu: (pds, t) in [0,1]."""
    base = truth.idle_kw[:, None] + truth.slope_kw[:, None] * \
        jnp.power(jnp.clip(cpu, 0.0, 1.0), truth.curve[:, None])
    eps = 1.0 + noise * jax.random.normal(key, cpu.shape)
    return base * eps


def _basis(u: jnp.ndarray, breaks: jnp.ndarray) -> jnp.ndarray:
    """[1, u, relu(u - b_k)...] hinge basis. u: (t,); breaks: (K,)."""
    cols = [jnp.ones_like(u), u]
    for k in range(breaks.shape[0]):
        cols.append(jnp.maximum(u - breaks[k], 0.0))
    return jnp.stack(cols, axis=-1)          # (t, K+2)


def _solve_spd(A: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Unrolled Cholesky solve for a small SPD system (K+2 = 5 here).

    Scalar elementwise ops in a fixed order — unlike LAPACK ``solve`` (and
    matmul normal equations), the result is bitwise identical under vmap,
    which the sim engine's batched-vs-sequential parity guarantee needs.
    """
    n = A.shape[-1]
    L = [[None] * n for _ in range(n)]
    for i in range(n):
        for j in range(i + 1):
            s = A[..., i, j]
            for k in range(j):
                s = s - L[i][k] * L[j][k]
            if i == j:
                L[i][j] = jnp.sqrt(jnp.clip(s, 1e-12, None))
            else:
                L[i][j] = s / L[j][j]
    y = []
    for i in range(n):
        s = b[..., i]
        for k in range(i):
            s = s - L[i][k] * y[k]
        y.append(s / L[i][i])
    x = [None] * n
    for i in reversed(range(n)):
        s = y[i]
        for k in range(i + 1, n):
            s = s - L[k][i] * x[k]
        x[i] = s / L[i][i]
    return jnp.stack(x, axis=-1)


def fit_pd_model(cpu: jnp.ndarray, power: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Least-squares piecewise-linear fit for ONE pd.
    cpu, power: (t,). Returns (coef (K+2,), breaks (K,))."""
    qs = jnp.linspace(0.0, 1.0, N_BREAKS + 2)[1:-1]
    breaks = jnp.quantile(cpu, qs)
    X = _basis(cpu, breaks)
    # ridge-regularized normal equations (stable under short windows),
    # assembled as reduce-of-multiply rather than dots: XLA dots pick
    # batch-extent-dependent accumulation orders, plain reduces have held
    # batch-invariant here (and fleet.power_model_from_history pins the
    # result behind an optimization_barrier; the sim parity tests would
    # catch a backend that reassociates these)
    XtX = jnp.sum(X[..., :, None] * X[..., None, :], axis=-3) \
        + 1e-4 * jnp.eye(X.shape[-1])
    Xty = jnp.sum(X * power[..., None], axis=-2)
    coef = _solve_spd(XtX, Xty)
    return coef, breaks


fit_pd_models = jax.jit(jax.vmap(fit_pd_model))      # (pds, t) -> batched


def pd_power(coef, breaks, u):
    """Predicted power at usage u (broadcasts over u).

    Evaluated as an ordered elementwise chain, not `basis @ coef`: a dot's
    accumulation order varies with surrounding batch dims, and the sim
    engine requires bitwise batched-vs-sequential parity."""
    p = coef[0] + coef[1] * u
    for k in range(breaks.shape[0]):
        p = p + coef[2 + k] * jnp.maximum(u - breaks[k], 0.0)
    return p


def pd_slope(coef, breaks, u):
    """Local slope pi(u) = d power / d usage."""
    shp = u.shape
    uu = u.reshape(-1)
    s = jnp.full_like(uu, coef[1])
    for k in range(breaks.shape[0]):
        s = s + jnp.where(uu > breaks[k], coef[2 + k], 0.0)
    return s.reshape(shp)


pd_power_b = jax.vmap(pd_power)          # batched over pds
pd_slope_b = jax.vmap(pd_slope)


def daily_mape(coef, breaks, cpu, power) -> jnp.ndarray:
    pred = pd_power(coef, breaks, cpu)
    return jnp.mean(jnp.abs(pred - power) / jnp.clip(power, 1e-6, None))


daily_mape_b = jax.jit(jax.vmap(daily_mape))


# ------------------------------------------------------- cluster aggregation

def usage_fractions(cpu_by_pd: jnp.ndarray) -> jnp.ndarray:
    """lambda^(PD): time-average usage fraction of each PD within a cluster.
    cpu_by_pd: (pds, t) -> (pds,). Paper: median variation ~1%."""
    tot = jnp.clip(cpu_by_pd.sum(axis=0, keepdims=True), 1e-9, None)
    return (cpu_by_pd / tot).mean(axis=1)


def cluster_power(coef, breaks, lam, u_cluster):
    """Cluster power at cluster CPU u (sum over PDs at u*lambda)."""
    u_pd = lam[:, None] * jnp.atleast_1d(u_cluster)[None, :]
    p = jax.vmap(pd_power, in_axes=(0, 0, 0))(coef, breaks, u_pd)
    return p.sum(axis=0).reshape(jnp.shape(u_cluster))


def cluster_slope(coef, breaks, lam, u_cluster):
    """pi^(c)(u) = sum_PD pi^(PD)(lambda*u) * lambda  (paper eq. 1)."""
    u_pd = lam[:, None] * jnp.atleast_1d(u_cluster)[None, :]
    s = jax.vmap(pd_slope, in_axes=(0, 0, 0))(coef, breaks, u_pd)
    s = (s * lam[:, None]).sum(axis=0)
    return s.reshape(jnp.shape(u_cluster))
