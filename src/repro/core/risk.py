"""Risk-aware VCC generation: forecast ensembles + CVaR-of-carbon-cost.

The paper's loop ("train day-ahead demand prediction models, and use
risk-aware optimization to generate ... carbon-aware VCCs") prices forecast
risk today through one static quantile inflation (eq. 3's alpha via
``forecast.relative_error_quantile``). This module closes the other half:
*optimize against the forecast uncertainty itself*.

Model
-----
* **Ensembles.** K day-ahead realizations of (inflexible usage, carbon
  intensity) are sampled by bootstrap-resampling whole DAYS of the
  empirical relative-error history the day cycle already tracks
  (``hist_uif_pred`` vs ``hist_uif`` for load; day-over-day intensity
  changes in ``carbon_hist`` as the persistence-error proxy for carbon).
  Resampling whole days preserves the intra-day error autocorrelation, and
  one day index is drawn per member FLEETWIDE, preserving the cross-cluster
  / cross-zone correlation that makes tail days tail days. Member 0 is
  always the point forecast itself.

* **CVaR objective.** For member costs X_1..X_K the optimizer targets
  CVaR_beta(X) = mean of the worst ``beta`` fraction of outcomes
  ("top-beta tail average"): ``beta = 1`` is the risk-neutral mean and
  recovers today's point-forecast path exactly; smaller beta is more
  risk-averse (``beta -> 0`` is the worst member). The PGD inner loop uses
  a smooth tilt — softmax member weights with sharpness
  ``kernels.vcc_pgd.ref.cvar_sharpness(beta)`` on per-cluster member
  costs — reduced over the member axis *inside* the vcc_pgd kernel. The
  member reduction is anchored on member 0, so K identical members (and
  the K=1 degenerate ensemble) reproduce the legacy optimizer bitwise.

Knobs: ``SimConfig.n_members`` / ``StageConfig.n_members`` set K (a static
shape); ``Scenario.risk_beta`` -> ``SimParams.risk_beta`` sets beta (a data
leaf, so scenario sweeps batch it). See README "Risk model".

This module holds NO solver machinery of its own: the CVaR epoch is
dispatched through ``repro.core.solver.pgd_epochs`` like every other PGD
loop, and the member-tilt math lives with the kernels
(``kernels.vcc_pgd.ref`` / the Pallas ensemble kernel).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels.vcc_pgd.ref import cvar_sharpness  # noqa: F401 (re-export)

f32 = jnp.float32

# clip bounds on resampled relative errors: one historical day must not
# produce a negative or absurd realization
ERR_LO, ERR_HI = -0.9, 3.0


# ------------------------------------------------------------------- CVaR

def cvar(x: jnp.ndarray, beta, axis: int = 0) -> jnp.ndarray:
    """Hard CVaR: mean of the worst ``ceil(beta * K)`` outcomes along
    ``axis`` (top-beta tail average). ``beta=1`` -> mean of all members;
    ``beta -> 0`` -> max. Exact and sort-based — reporting/tests; the
    optimizer uses ``soft_cvar``. ``beta`` may be a traced scalar (the
    tail count becomes a mask over the sorted members, so this jits and
    vmaps — risk sweeps carry beta as a data leaf)."""
    K = x.shape[axis]
    xs = jnp.flip(jnp.sort(jnp.moveaxis(x, axis, -1), axis=-1), axis=-1)
    k = jnp.clip(jnp.ceil(jnp.asarray(beta, f32) * K), 1.0, K)
    w = (jnp.arange(K, dtype=f32) < k).astype(x.dtype) / k.astype(x.dtype)
    return jnp.sum(xs * w, axis=-1)


def soft_cvar(x: jnp.ndarray, beta, axis: int = 0) -> jnp.ndarray:
    """Differentiable CVaR surrogate: softmax-tilted member average with
    sharpness ``cvar_sharpness(beta)`` on mean-centered, scale-normalized
    outcomes. ``beta`` may be traced. Properties (tested): equals the mean
    at ``beta=1``, is monotone non-increasing in beta (more risk-averse =
    smaller beta = larger value), and lies in [mean(x), max(x)]."""
    s = cvar_sharpness(beta)
    z = x - jnp.mean(x, axis=axis, keepdims=True)
    scale = jnp.mean(jnp.abs(z), axis=axis, keepdims=True) + 1e-9
    w = jax.nn.softmax(s * z / scale, axis=axis)
    return jnp.sum(w * x, axis=axis)


# ------------------------------------------------------------- ensembles

def relative_error_days(pred_hist: jnp.ndarray, actual_hist: jnp.ndarray
                        ) -> jnp.ndarray:
    """Empirical per-day relative-error profiles (act - pred) / |pred|.
    pred/actual: (..., D, 24) -> (..., D, 24)."""
    return (actual_hist - pred_hist) / jnp.clip(jnp.abs(pred_hist), 1e-9,
                                                None)


def _member_day_idx(key, n_members: int, n_days: int) -> jnp.ndarray:
    """One resampled history-day index per member, shared fleetwide.
    Member 0 is pinned to 'no error' by the callers (index unused)."""
    return jax.random.randint(key, (n_members,), 0, n_days)


def sample_uif_ensemble(key, uif_pred, hist_uif_pred, hist_uif,
                        n_members: int) -> jnp.ndarray:
    """K realizations of next-day inflexible usage. uif_pred: (n, 24);
    hist_*: (n, D, 24) rolling prediction/actual history. Returns
    (K, n, 24) with member 0 == the point forecast bitwise."""
    err = relative_error_days(hist_uif_pred, hist_uif)       # (n, D, 24)
    idx = _member_day_idx(key, n_members, err.shape[1])
    e = jnp.clip(err[:, idx], ERR_LO, ERR_HI)                # (n, K, 24)
    e = jnp.moveaxis(e, 1, 0).at[0].set(0.0)                 # (K, n, 24)
    return jnp.clip(uif_pred[None] * (1.0 + e), 0.0, None)


def sample_eta_ensemble(key, fc_z, carbon_hist, zmap, n_members: int
                        ) -> jnp.ndarray:
    """K realizations of next-day carbon intensity per cluster.

    fc_z: (z, 24) day-ahead zone forecast; carbon_hist: (z, D, 24) actual
    zone history; zmap: (n,) zone of cluster. Day-ahead forecast errors are
    proxied by the empirical day-over-day relative change of the actual
    intensity (persistence error) — the quantity ``carbon_hist`` already
    tracks. Returns (K, n, 24) with member 0 == fc_z[zmap] bitwise.
    """
    prev = carbon_hist[:, :-1]
    dz = (carbon_hist[:, 1:] - prev) / jnp.clip(jnp.abs(prev), 1e-9, None)
    idx = _member_day_idx(key, n_members, dz.shape[1])
    e = jnp.clip(dz[:, idx], ERR_LO, ERR_HI)                 # (z, K, 24)
    e = jnp.moveaxis(e, 1, 0).at[0].set(0.0)                 # (K, z, 24)
    eta_ens_z = jnp.clip(fc_z[None] * (1.0 + e), 1e-6, None)
    return eta_ens_z[:, zmap]


def day_ensembles(key, n_members: int, uif_pred, hist_uif_pred, hist_uif,
                  fc_z, carbon_hist, zmap, risk_beta
                  ) -> Dict[str, jnp.ndarray]:
    """Sample the day's forecast ensembles (the optimize_stage hook).
    Returns the kwargs of ``attach_ensemble``. jit/vmap-safe."""
    k_u, k_c = jax.random.split(key)
    return {
        "uif_ens": sample_uif_ensemble(k_u, uif_pred, hist_uif_pred,
                                       hist_uif, n_members),
        "eta_ens": sample_eta_ensemble(k_c, fc_z, carbon_hist, zmap,
                                       n_members),
        "risk_beta": jnp.asarray(risk_beta, f32),
    }


def attach_ensemble(prob, eta_ens, uif_ens, risk_beta):
    """Attach ensemble axes to a point-forecast VCCProblem.

    Member power curves are the problem's own local linearization around
    nominal: pow_nom_k = pow_nom + pi * (uif_k - u_if) — the same model
    the PGD gradient already assumes, so no extra power-model fits. The
    risk-aware bounds (u_if_q quantile, eq. 3 alpha) stay as-is: ensembles
    change the OBJECTIVE, not the feasible set.
    """
    pow_nom_ens = prob.pow_nom[None] + prob.pi[None] * (uif_ens
                                                        - prob.u_if[None])
    return dataclasses.replace(prob, eta_ens=eta_ens,
                               pow_nom_ens=pow_nom_ens,
                               risk_beta=jnp.asarray(risk_beta, f32))


# ------------------------------------------------------------- objectives

def member_objectives(p, delta, mu) -> jnp.ndarray:
    """Per-member total day cost (K,) of ``delta`` under each forecast
    realization (carbon term + hard per-cluster peak term, eq. 4 shape)."""
    tau24 = p.tau[:, None] / 24.0
    peak_price = p.lambda_p + mu[p.campus]

    def one(eta_k, pow_nom_k):
        pow_h = pow_nom_k + p.pi * delta * tau24
        y = pow_h.max(axis=1)
        return p.lambda_e * jnp.sum(eta_k * pow_h) \
            + jnp.sum(peak_price * y)

    return jax.vmap(one)(p.eta_ens, p.pow_nom_ens)


def soft_cvar_objective(p, delta, mu) -> jnp.ndarray:
    """Fleet-level smooth risk surrogate: soft CVaR of the per-member
    total costs at the problem's ``risk_beta``. The PGD step applies the
    same tilt (same sharpness, same deviation scale —
    ``kernels.vcc_pgd.ref.cvar_member_weights``) PER CLUSTER, a separable
    relaxation of this quantity; improvement is asserted in
    tests/test_risk.py."""
    return soft_cvar(member_objectives(p, delta, mu), p.risk_beta, axis=0)


def cvar_objective(p, delta, mu, beta=None) -> jnp.ndarray:
    """Exact (hard) CVaR of the per-member total costs; ``beta`` defaults
    to the problem's ``risk_beta`` (traced values work — see ``cvar``)."""
    b = p.risk_beta if beta is None else beta
    return cvar(member_objectives(p, delta, mu), b, axis=0)
