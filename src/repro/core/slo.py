"""SLO-violation detection + feedback loop (paper §III-B2).

SLO: a cluster's daily flexible compute demand may be curtailed at most ~1
day/month (violation probability <= 0.03). Detection: if actual daily
reservation demand crowds the VCC budget (comes within ``margin`` of
sum_h VCC(h)) for two days in a row, shaping is disabled for that cluster
for ``pause_days`` (paper: a week) so the forecasters re-adapt.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

f32 = jnp.float32


@dataclass(frozen=True)
class SLOConfig:
    margin: float = 1.0           # demand/VCC ratio considered "crowded"
    pause_days: int = 7
    target_violation_rate: float = 0.03    # ~1 day / month
    # a day counts as violated when unmet flexible work exceeds this
    # fraction of the day's arrivals (relative, so the detector fires the
    # same way on a 10-CPU synthetic cluster and a 10k-CPU production one)
    rel_tol: float = 1e-3


def init_state(n_clusters: int):
    return {
        "crowded_streak": jnp.zeros((n_clusters,), jnp.int32),
        "pause_left": jnp.zeros((n_clusters,), jnp.int32),
        "violation_days": jnp.zeros((n_clusters,), jnp.int32),
        "observed_days": jnp.zeros((n_clusters,), jnp.int32),
    }


def update(state, cfg: SLOConfig, daily_reservations, vcc_budget,
           flexible_unmet, arrived):
    """One end-of-day update.
    daily_reservations: (n,) realized total reservation demand;
    vcc_budget: (n,) sum_h VCC(h); flexible_unmet: (n,) CPU-h of flexible
    demand that did not run within the day (true SLO violation signal);
    arrived: (n,) CPU-h of flexible arrivals (violation scale reference).
    Returns (new_state, shaped_allowed (n,) bool for NEXT day).

    While a pause is active the cluster is unshaped (VCC = capacity), so
    "crowded" days carry no signal about the shaped curve — the streak is
    frozen until the pause expires. (The old behavior kept accumulating
    and re-triggered a full pause, so a persistently busy cluster never
    resumed shaping.)"""
    paused = state["pause_left"] > 0
    crowded = daily_reservations >= cfg.margin * vcc_budget
    streak = jnp.where(paused, state["crowded_streak"],
                       jnp.where(crowded, state["crowded_streak"] + 1, 0))
    trigger = (~paused) & (streak >= 2)
    pause = jnp.where(trigger, cfg.pause_days,
                      jnp.maximum(state["pause_left"] - 1, 0))
    violated = flexible_unmet > cfg.rel_tol * arrived
    new = {
        "crowded_streak": jnp.where(trigger, 0, streak),
        "pause_left": pause,
        "violation_days": state["violation_days"] + violated.astype(
            jnp.int32),
        "observed_days": state["observed_days"] + 1,
    }
    return new, pause == 0


def violation_rate(state):
    return state["violation_days"] / jnp.clip(state["observed_days"], 1,
                                              None)
