"""Generic projected-gradient solver layer — THE core-side PGD machinery.

Every optimizer in this repo is a thin assembly over the same pieces:

  * ``project_conservation`` — exact bisection projection of each row onto
    the conservation polytope {sum = 0} ∩ [lo, ub] (the jnp oracle lives
    in ``kernels.vcc_pgd.ref`` so the Pallas kernels can mirror it op for
    op in VMEM; this module is the single core-layer entry point).
  * ``smooth_peak`` / ``peak_temperature`` — the differentiable softmax
    relaxation of the hard hourly peak and its problem-scaled temperature.
  * ``scaled_lr`` — per-cluster learning-rate normalization for the
    linearized carbon + peak gradient.
  * ``pgd_epochs`` / ``joint_epochs`` — the fused-epoch dispatch
    convention shared fleet-wide: ``use_pallas=None`` auto-selects the
    Pallas kernel on TPU and the jnp oracle elsewhere; ``interpret=True``
    drives the kernel through the Pallas interpreter (CPU parity tests).
  * ``dual_ascent`` / ``campus_dual_update`` — the outer loop: scan of
    [inner PGD epoch → multiplier update] with clipped ascent on the
    campus power couplings.
  * ``minimize_linear`` — the EXACT minimizer of a linear objective over
    the conservation polytope (the closed form of constant-gradient PGD,
    which the spatial pre-shift used to iterate).

``core.vcc`` (temporal, eq. 4), ``core.spatial`` (spatial pre-shift and
the joint spatio-temporal solve), and ``core.risk`` (CVaR ensembles) hold
NO private copies of this machinery — they parameterize it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.vcc_pgd import ref as _pgd_ref

f32 = jnp.float32


# ------------------------------------------------------------- projections

def project_conservation(z, lo, ub, iters: int = 50):
    """Euclidean projection of each row of ``z`` onto {sum=0} ∩ [lo, ub]
    via bisection on the shift nu: sum(clip(z - nu, lo, ub)) = 0. Exact to
    bisection tolerance; elementwise + ordered ops only, so it is bitwise
    batch-invariant (the sim engine's parity contract rides on this).
    Delegates to the kernel package's jnp oracle — the Pallas kernels
    mirror the same loop in VMEM."""
    return _pgd_ref.project_row(z, lo, ub, iters)


def minimize_linear(cost, lo, ub):
    """Exact row-wise minimizer of <cost, x> over {sum x = 0} ∩ [lo, ub]
    (requires lo <= 0 <= ub so x = 0 is feasible).

    This is the closed form that constant-gradient projected descent
    converges to: start every coordinate at its lower bound and spend the
    budget ``-sum(lo)`` on coordinates in increasing-cost order (classic
    exchange argument; ``vcc.greedy_linear_reference`` is the independent
    numpy oracle). Vectorized with sort + cumsum: jit/vmap-safe, and with
    lo = ub = 0 the result is exactly 0 in every coordinate (the
    mobility=0 identity the golden trace depends on)."""
    order = jnp.argsort(cost, axis=1)
    room = jnp.take_along_axis(ub - lo, order, axis=1)
    budget = -jnp.sum(lo, axis=1, keepdims=True)
    cum = jnp.cumsum(room, axis=1)
    add = jnp.clip(budget - (cum - room), 0.0, room)
    inv = jnp.argsort(order, axis=1)
    return lo + jnp.take_along_axis(add, inv, axis=1)


# ---------------------------------------------------------- peak relaxation

def smooth_peak(pow_h, temp):
    """Differentiable softmax-peak and its weights. pow_h: (n, H)."""
    w = jax.nn.softmax(pow_h / temp, axis=1)
    return jnp.sum(w * pow_h, axis=1), w


def peak_temperature(pow_nom, temp_frac):
    """Problem-scaled softmax-peak temperature (fraction of mean power)."""
    return temp_frac * jnp.clip(pow_nom.mean(), 1e-6, None)


# --------------------------------------------------------------- lr scaling

def scaled_lr(lr, pi, tau, eta, lambda_e, lambda_p):
    """Per-cluster (n, 1) learning rate for the linearized carbon + peak
    objective: the raw gradient scales like pi * tau/24 * (lambda_e * eta
    + lambda_p), so divide it out to make ``lr`` dimensionless."""
    g_scale = jnp.clip((pi * tau[:, None] / 24.0).max(axis=1,
                                                      keepdims=True),
                       1e-9, None)
    return lr / (g_scale * jnp.clip(
        lambda_e * eta.max(axis=1, keepdims=True) + lambda_p, 1e-9,
        None))


# ------------------------------------------------------------- dual ascent

def campus_dual_update(mu, y, campus, campus_limit, rho):
    """Clipped dual ascent on the campus power couplings: mu grows where
    the summed cluster peaks ``y`` exceed the campus contract."""
    campus_pow = jax.ops.segment_sum(y, campus,
                                     num_segments=campus_limit.shape[0])
    return jnp.clip(mu + rho * (campus_pow - campus_limit)
                    / jnp.clip(campus_limit, 1e-9, None), 0.0, None)


def dual_ascent(inner, dual_update, x0, mu0, outer_iters: int,
                diag_fn=None):
    """Generic outer loop: ``outer_iters`` rounds of [x = inner(x, mu);
    mu = dual_update(x, mu)] under lax.scan. ``x`` may be any pytree
    (the joint solve carries a (delta, s) tuple).

    ``diag_fn(x_prev, x_new, mu_new) -> pytree`` (optional) emits one
    per-round diagnostic record through the scan's ys; the return becomes
    ``(x, mu, ys)`` with each ys leaf stacked (outer_iters, ...). With
    ``diag_fn=None`` the traced graph is EXACTLY the legacy two-value
    scan (the telemetry=off collapse contract rides on this)."""
    def outer(carry, _):
        x, mu = carry
        x_new = inner(x, mu)
        mu = dual_update(x_new, mu)
        y = None if diag_fn is None else diag_fn(x, x_new, mu)
        return (x_new, mu), y

    (x, mu), ys = jax.lax.scan(outer, (x0, mu0), None, length=outer_iters)
    if diag_fn is None:
        return x, mu
    return x, mu, ys


# ---------------------------------------------------------- epoch dispatch

def pgd_epochs(prob, delta, mu, lo, ub, lr_eff, temp, iters: int, *,
               use_pallas: Optional[bool] = None, interpret: bool = False):
    """``iters`` fused temporal PGD steps (gradient + exact conservation
    projection) for a VCCProblem — the fleet-wide dispatch convention:
    ``use_pallas=None`` auto-selects the Pallas kernel on TPU and the jnp
    oracle elsewhere; ``interpret=True`` runs the kernel through the
    Pallas interpreter (CPU tests). Problems carrying ensemble axes route
    to the CVaR member-reduction epoch."""
    from repro.kernels.vcc_pgd import ops as _k
    return _k.pgd_epoch(prob, delta, mu, lo, ub, lr_eff, temp, iters,
                        use_pallas=use_pallas, interpret=interpret)


def joint_epochs(prob, delta, s, mu, lo_s, ub_s, lr_d, lr_s, temp,
                 iters: int, *, use_pallas: Optional[bool] = None,
                 interpret: bool = False):
    """``iters`` joint spatio-temporal steps. Each step runs the fused
    per-cluster kernel (temporal bounds recomputed from the shifted tau,
    delta gradient + projection, per-cluster shift gradient — see
    ``kernels.vcc_pgd.ref.joint_step_arrays``) and then descends +
    projects the fleet-coupled shift ``s`` onto {sum_c s = 0} ∩
    [lo_s, ub_s] OUTSIDE the cluster-tiled kernel (the conservation over
    clusters cannot be tiled)."""
    from repro.kernels.vcc_pgd import ops as _k

    def body(i, carry):
        d, sv = carry
        d, g_s = _k.joint_step(prob, d, sv, mu, lr_d, temp,
                               use_pallas=use_pallas, interpret=interpret)
        z = sv - lr_s * g_s[:, 0]
        sv = project_conservation(z[None, :], lo_s[None, :],
                                  ub_s[None, :])[0]
        return (d, sv)

    return jax.lax.fori_loop(0, iters, body, (delta, s))
