"""Beyond-paper extension: spatial shifting of flexible compute (paper §V
names this as the planned next step; we implement the day-ahead layer).

Given per-cluster risk-aware daily flexible budgets tau_c, redistribute
daily totals across clusters (subject to per-cluster headroom) to minimize
expected carbon, THEN run the paper's temporal VCC optimization with the
shifted budgets. Conservation: sum_c tau'_c = sum_c tau_c; movement is
limited to ``mobility`` (fraction of a cluster's flexible work that is
location-flexible) and to clusters with spare daily headroom.

This is the same projected-gradient machinery as vcc.py, applied across the
cluster axis with carbon price = daily usage-weighted intensity.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.vcc import VCCProblem, project_conservation

f32 = jnp.float32


def spatial_shift(p: VCCProblem, *, mobility: float = 0.3,
                  iters: int = 200, lr: float = 0.1
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (tau_shifted (n,), carbon_price (n,)).

    carbon_price_c = mean_h eta(c,h) * pi(c,h): the marginal kgCO2e of
    placing one CPU-day at cluster c (before temporal shaping).
    """
    price = (p.eta * p.pi).mean(axis=1)                      # (n,)
    tau = p.tau
    # headroom: how much extra daily flexible CPU the cluster could run
    room_h = jnp.clip(p.capacity[:, None] / p.ratio - p.u_if, 0.0, None)
    headroom = jnp.clip(room_h.sum(axis=1) - tau, 0.0, None)
    lo = -mobility * tau                                     # can export
    ub = jnp.minimum(mobility * tau.sum() / jnp.maximum(tau.shape[0], 1),
                     headroom)                               # can import

    def body(i, d):
        g = price
        d = d - lr * (g / jnp.clip(jnp.abs(price).max(), 1e-9, None)) \
            * tau.mean()
        return project_conservation(d[None, :], lo[None, :],
                                    ub[None, :])[0]

    shift = jax.lax.fori_loop(0, iters, body, jnp.zeros_like(tau))
    return jnp.clip(tau + shift, 0.0, None), price


def spatial_shift_batched(p: VCCProblem, *, mobility=0.3, iters: int = 200,
                          lr: float = 0.1):
    """vmap spatial_shift over a leading batch axis of a stacked VCCProblem.
    ``mobility`` may be a scalar or a (batch,) array (scenario sweeps)."""
    mob = jnp.asarray(mobility, f32)
    if mob.ndim == 0:
        mob = jnp.broadcast_to(mob, (jax.tree_util.tree_leaves(p)[0].shape[0],))
    return jax.vmap(lambda q, m: spatial_shift(q, mobility=m, iters=iters,
                                               lr=lr))(p, mob)
