"""Spatial flexibility: day-ahead shifting of flexible compute across
clusters (paper §V names this as the planned next step).

Two layers, both assemblies over ``repro.core.solver``:

* ``spatial_shift`` — the decoupled GREEDY pre-shift: move daily flexible
  budgets tau toward carbon-cheap clusters (exact linear minimizer over
  the fleet-conservation polytope), then run the paper's temporal VCC
  optimization on the shifted budgets. Fast, but blind to the temporal
  solve: a cluster whose green hours are capacity-saturated still imports
  work it cannot shape into them.

* ``solve_joint`` — JOINT spatio-temporal optimization: the temporal
  deviations delta (n, H) and the daily shift s (n,) are descended
  TOGETHER, with the temporal bounds recomputed from the shifted budgets
  tau + s inside every fused step (``kernels.vcc_pgd.joint_step``). The
  sequential two-phase answer seeds the joint descent and a best-of
  safeguard keeps the result from ever being worse than it (on both the
  nominal objective and its carbon term). A static ``mobility == 0``
  collapses to the EXACT legacy temporal graph, bitwise — the same
  contract the K=1 risk ensemble keeps.

Shift bounds: a cluster may export at most ``mobility * tau_c`` (the
location-flexible fraction of its own budget) and import at most
``min(mobility * tau_c, headroom_c)`` — size-aware (proportional to the
cluster's own flexible budget) and headroom-aware (it must have the spare
daily machine capacity to actually run the work).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import solver, vcc
from repro.core.vcc import VCCProblem, VCCSolution

f32 = jnp.float32


def carbon_price(p: VCCProblem) -> jnp.ndarray:
    """(n,) marginal kgCO2e of placing one CPU-day at each cluster
    (before temporal shaping): mean_h eta(c,h) * pi(c,h)."""
    return (p.eta * p.pi).mean(axis=1)


def shift_bounds(p: VCCProblem, mobility) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-cluster (lo, ub) for the daily shift s (negative = export).

    Export is capped at ``mobility * tau_c``; import at
    ``min(mobility * tau_c, headroom_c)`` where headroom is the spare
    daily machine capacity beyond the cluster's own flexible budget. Both
    caps scale with the cluster's own size (a uniform fleet-average
    import cap would let small clusters import work they cannot hold)."""
    room_h = jnp.clip(p.capacity[:, None] / p.ratio - p.u_if, 0.0, None)
    headroom = jnp.clip(room_h.sum(axis=1) - p.tau, 0.0, None)
    lo = -mobility * p.tau
    ub = jnp.minimum(mobility * p.tau, headroom)
    return lo, ub


def spatial_shift(p: VCCProblem, *, mobility: float = 0.3
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy pre-shift: returns (tau_shifted (n,), carbon_price (n,)).

    The objective is linear in s (constant gradient), so the minimizer
    over {sum_c s = 0} ∩ [lo, ub] is exact (``solver.minimize_linear`` —
    the closed form of the constant-gradient PGD loop this used to
    iterate). ``mobility`` may be a float or a traced scalar; mobility=0
    collapses the bounds to {0} and returns tau bitwise."""
    price = carbon_price(p)
    lo, ub = shift_bounds(p, mobility)
    shift = solver.minimize_linear(price[None, :], lo[None, :],
                                   ub[None, :])[0]
    return jnp.clip(p.tau + shift, 0.0, None), price


def spatial_shift_batched(p: VCCProblem, *, mobility=0.3):
    """vmap spatial_shift over a leading batch axis of a stacked VCCProblem.
    ``mobility`` may be a scalar or a (batch,) array (scenario sweeps)."""
    mob = jnp.asarray(mobility, f32)
    if mob.ndim == 0:
        mob = jnp.broadcast_to(mob,
                               (jax.tree_util.tree_leaves(p)[0].shape[0],))
    return jax.vmap(lambda q, m: spatial_shift(q, mobility=m))(p, mob)


# ------------------------------------------------- joint spatio-temporal

def joint_power(p: VCCProblem, delta, s):
    """Hourly power under (delta, s): the local linearization around the
    ORIGINAL nominal point, including the baseline term pi * s / 24 from
    moving the flat daily budget itself — the term the sequential
    pre-shift path ignores (its pow_nom is linearized at the unshifted
    nominal)."""
    return p.pow_nom + p.pi * (delta * (p.tau + s)[:, None]
                               + s[:, None]) / 24.0


def joint_carbon(p: VCCProblem, delta, s):
    """Model-consistent expected carbon (kg) of the joint point."""
    return jnp.sum(p.eta * joint_power(p, delta, s))


def joint_objective(p: VCCProblem, delta, s, mu=None):
    """Nominal day cost of (delta, s): carbon price + hard hourly peak
    (eq. 4 shape). ``mu=None`` evaluates the primal objective (lambda_p
    only) — the scale both best-of candidates are compared on."""
    pow_h = joint_power(p, delta, s)
    y = pow_h.max(axis=1)
    price = p.lambda_p if mu is None else p.lambda_p + mu[p.campus]
    return p.lambda_e * joint_carbon(p, delta, s) + jnp.sum(price * y)


def solve_joint(p: VCCProblem, mobility, *, inner_iters: int = 80,
                outer_iters: int = 20, joint_inner: int = 25,
                joint_outer: int = 8, lr: float = 0.5, lr_s: float = 0.15,
                temp_frac: float = 0.02, rho: float = 0.2,
                use_pallas: Optional[bool] = None, interpret: bool = False,
                telemetry: bool = False):
    """Joint spatio-temporal VCC optimization.

    Returns (solution, tau_joint (n,), s (n,)): the temporal deviations
    and VCC curves of ``solution`` are consistent with the SHIFTED daily
    budgets ``tau_joint = clip(tau + s, 0)``.

    Pipeline:
      1. static collapse — a Python-scalar ``mobility == 0`` returns the
         EXACT legacy temporal solve (bitwise; the spatial variable never
         enters the graph — the K=1 risk-ensemble contract, spatially);
      2. sequential warm start — greedy ``spatial_shift`` + temporal
         ``solve_vcc`` at the shifted budgets (the pre-shift baseline);
      3. joint refinement — ``solver.dual_ascent`` over
         ``solver.joint_epochs``: fused steps recompute the temporal
         bounds from tau + s and descend (delta, s) together, so budget
         flows out of clusters whose green hours are saturated;
      4. best-of safeguard — the joint point is kept only if it (weakly)
         improves BOTH the nominal objective and its carbon term over the
         warm start, evaluated model-consistently (``joint_objective`` /
         ``joint_carbon``, which include the pi*s/24 baseline term the
         sequential pass ignores). Joint is therefore never worse than
         sequential by construction. The switch is fleet-wide and
         all-or-nothing — conservative by design: in slack fleets where
         the greedy pre-shift is already optimal (bounds not binding)
         the joint path simply reduces to the sequential answer; it pays
         off in supply-tight regimes (see
         ``vcc.synthetic_zonal_problem`` / the capacity-squeezed
         mobility sweep), which is where the gates measure it.

    ``telemetry=True`` appends a solver-diagnostics dict to the return
    (``(sol, tau_j, s, diag)``): the warm-start temporal solve's
    convergence trajectories, ``vcc.solution_diagnostics`` at the FINAL
    joint-consistent point, and ``joint_winner`` — 1.0 when the best-of
    safeguard kept the joint refinement, 0.0 when it fell back to the
    sequential warm start (the static mobility==0 collapse reports 0.0:
    the joint path never ran). ``telemetry=False`` (default) traces the
    exact legacy graph.
    """
    if not isinstance(mobility, jnp.ndarray) and float(mobility) == 0.0:
        sol = vcc.solve_vcc(p, inner_iters=inner_iters,
                            outer_iters=outer_iters, lr=lr,
                            temp_frac=temp_frac, rho=rho,
                            use_pallas=use_pallas, interpret=interpret,
                            telemetry=telemetry)
        if telemetry:
            sol, diag = sol
            diag["joint_winner"] = jnp.zeros((), f32)
            return sol, p.tau, jnp.zeros_like(p.tau), diag
        return sol, p.tau, jnp.zeros_like(p.tau)

    mob = jnp.asarray(mobility, f32)
    # 2. sequential two-phase warm start
    tau_sh, _ = spatial_shift(p, mobility=mob)
    p_seq = dataclasses.replace(p, tau=tau_sh)
    sol_seq = vcc.solve_vcc(p_seq, inner_iters=inner_iters,
                            outer_iters=outer_iters, lr=lr,
                            temp_frac=temp_frac, rho=rho,
                            use_pallas=use_pallas, interpret=interpret,
                            telemetry=telemetry)
    diag_seq = None
    if telemetry:
        sol_seq, diag_seq = sol_seq
    lo_s, ub_s = shift_bounds(p, mob)
    s0 = jnp.clip(tau_sh - p.tau, lo_s, ub_s)

    # 3. joint refinement from (delta_seq, s0)
    temp = solver.peak_temperature(p.pow_nom, temp_frac)
    lr_d = solver.scaled_lr(lr, p.pi, p.tau, p.eta, p.lambda_e, p.lambda_p)
    # shift-gradient scale: g_s ~ lambda_e * mean_h(eta pi) + price pi / 24
    g_norm = jnp.clip((p.lambda_e * (p.eta * p.pi).mean(axis=1)
                       + p.lambda_p * p.pi.mean(axis=1) / 24.0).max(),
                      1e-9, None)
    lr_s_eff = lr_s * jnp.clip(p.tau.mean(), 1e-6, None) / g_norm

    def inner(x, mu):
        d, s = x
        return solver.joint_epochs(p, d, s, mu, lo_s, ub_s, lr_d, lr_s_eff,
                                   temp, joint_inner, use_pallas=use_pallas,
                                   interpret=interpret)

    def dual_update(x, mu):
        d, s = x
        y = joint_power(p, d, s).max(axis=1)
        return solver.campus_dual_update(mu, y, p.campus, p.campus_limit,
                                         rho)

    (d_j, s_j), mu_j = solver.dual_ascent(inner, dual_update,
                                          (sol_seq.delta, s0), sol_seq.mu,
                                          joint_outer)

    # 4. best-of safeguard: joint must (weakly) dominate the warm start
    take = (joint_objective(p, d_j, s_j) <= joint_objective(p, sol_seq.delta,
                                                            s0)) \
        & (joint_carbon(p, d_j, s_j) <= joint_carbon(p, sol_seq.delta, s0))
    delta = jnp.where(take, d_j, sol_seq.delta)
    s = jnp.where(take, s_j, s0)
    mu = jnp.where(take, mu_j, sol_seq.mu)

    tau_j = jnp.clip(p.tau + s, 0.0, None)
    pf = dataclasses.replace(p, tau=tau_j)
    lo, ub, feasible = vcc.delta_bounds(pf)
    delta = jnp.where(feasible[:, None], delta, 0.0)
    pow_h = joint_power(p, delta, s)
    y = pow_h.max(axis=1)
    vcc_shaped = (pf.u_if + (1.0 + delta) * tau_j[:, None] / 24.0) * pf.ratio
    vcc_curve = jnp.where(feasible[:, None],
                          jnp.minimum(vcc_shaped, pf.capacity[:, None]),
                          pf.capacity[:, None])
    sol = VCCSolution(delta=delta, y=y, vcc=vcc_curve, shaped=feasible,
                      mu=mu, objective=joint_objective(p, delta, s, mu))
    if telemetry:
        diag = {"obj_cluster_traj": diag_seq["obj_cluster_traj"],
                "step_max_traj": diag_seq["step_max_traj"],
                **vcc.solution_diagnostics(pf, delta, mu,
                                           temp_frac=temp_frac),
                "joint_winner": take.astype(f32)}
        return sol, tau_j, s, diag
    return sol, tau_j, s


def solve_joint_batched(p: VCCProblem, mobility, **kw):
    """vmap solve_joint over a leading batch axis of a stacked VCCProblem.
    ``mobility`` may be a scalar or a (batch,) array (mobility sweeps);
    batched mobility is always traced, so the joint graph runs for every
    row (mobility=0 rows pin s to 0 through the bounds)."""
    mob = jnp.asarray(mobility, f32)
    if mob.ndim == 0:
        mob = jnp.broadcast_to(mob,
                               (jax.tree_util.tree_leaves(p)[0].shape[0],))
    return jax.vmap(lambda q, m: solve_joint(q, m, **kw))(p, mob)
