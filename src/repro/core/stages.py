"""The staged CICS day cycle — the ONE implementation of the paper's loop.

Every simulated day is the same pipeline (paper Fig. 4/5):

  carbon_stage    — scenario-perturbed grid simulation + day-ahead
                    intensity forecast per zone
  power_stage     — refit PD piecewise-linear power models on history
  forecast_stage  — day-ahead U_IF(h), T_UF(d), T_R(d), R(h), trailing
                    -error quantiles -> Theta, alpha (eq. 3)
  optimize_stage  — fleetwide risk-aware VCCs (eq. 4) + optional spatial
                    pre-shift; PGD inner loop via kernels.vcc_pgd; with
                    StageConfig.n_members > 1 the objective is a CVaR
                    over K forecast-ensemble members (core.risk) at
                    SimParams.risk_beta
  (SLO gate)      — paused clusters get VCC = machine capacity
  observe_stage   — Borg-like admission on ACTUAL load, shaped + unshaped
                    counterfactual in the same trace
  slo_stage       — violation detection + shaping-pause feedback

Each stage is a pure, jit/vmap-safe function from array pytrees to array
pytrees, with an ``optimization_barrier`` materialization pin at its
boundary: XLA must not re-fuse (and re-round) a stage's output when its
consumers change, or the sim engine's bitwise batched==sequential parity
contract breaks. ``make_day_step`` composes the stages into one pure day;
``burnin_step``/``make_init`` build a burned-in state under ``lax.scan``.

Both drivers are thin adapters over this module: ``sim.engine`` scans/vmaps
``make_day_step`` across days and a (scenario x seed) batch, and the legacy
``core.fleet`` API steps the SAME jitted day (``jitted_day_step``) from a
mutable ``FleetState``. There is no second copy of the day cycle.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (admission, carbon, forecast, mpc, power, risk,
                        slo, spatial, stats, vcc)

f32 = jnp.float32

# ordered sum over the last axis: the batch-invariant reduction primitive
# (single definition — the parity contract depends on these staying one op)
hour_sum = admission.hour_sum


# jax<=0.4 vmap-rule shim for optimization_barrier — registered by the
# lowest barrier-emitting module (forecast.ewma_update pins its products)
forecast.register_barrier_batching()


# ------------------------------------------------------------- fleet synth

def cluster_truth(key, n: int):
    """Latent per-cluster load-generating processes."""
    ks = jax.random.split(key, 10)
    capacity = jnp.exp(jax.random.normal(ks[0], (n,)) * 0.4 + 2.3)  # ~10 CPU
    flex_share = jnp.clip(0.08 + 0.5 * jax.random.uniform(ks[1], (n,)),
                          0.05, 0.6)
    base_if = capacity * (0.35 + 0.2 * jax.random.uniform(ks[2], (n,)))
    diurnal_amp = 0.15 + 0.2 * jax.random.uniform(ks[3], (n,))
    peak_hour = 8.0 + 10.0 * jax.random.uniform(ks[4], (n,))
    weekly_amp = 0.05 + 0.1 * jax.random.uniform(ks[5], (n,))
    noise = 0.02 + 0.06 * jax.random.uniform(ks[6], (n,))
    arr_level = capacity * flex_share * (0.5 + 0.4 *
                                         jax.random.uniform(ks[7], (n,)))
    ratio_a = 1.15 + 0.3 * jax.random.uniform(ks[8], (n,))
    ratio_b = -0.05 - 0.08 * jax.random.uniform(ks[9], (n,))
    return {"capacity": capacity, "flex_share": flex_share,
            "base_if": base_if, "diurnal_amp": diurnal_amp,
            "peak_hour": peak_hour, "weekly_amp": weekly_amp,
            "noise": noise, "arr_level": arr_level,
            "ratio_a": ratio_a, "ratio_b": ratio_b}


def sample_inflexible(key, truth, day):
    """Actual inflexible hourly usage for one day. (n, 24)."""
    hours = jnp.arange(24, dtype=f32)
    d = jnp.minimum(jnp.abs(hours[None] - truth["peak_hour"][:, None]),
                    24 - jnp.abs(hours[None] - truth["peak_hour"][:, None]))
    diurnal = 1.0 + truth["diurnal_amp"][:, None] * jnp.exp(
        -0.5 * (d / 4.0) ** 2)
    weekly = 1.0 + truth["weekly_amp"][:, None] * jnp.cos(
        2 * jnp.pi * (day % 7) / 7.0)
    eps = 1.0 + truth["noise"][:, None] * jax.random.normal(
        key, (truth["base_if"].shape[0], 24))
    return truth["base_if"][:, None] * diurnal * weekly * eps


def sample_arrivals(key, truth, day):
    """Flexible CPU-hour arrivals per hour. (n, 24)."""
    hours = jnp.arange(24, dtype=f32)
    prof = 0.6 + 0.8 * jnp.exp(-0.5 * ((hours[None] - 11.0) / 5.0) ** 2)
    weekly = 1.0 + 0.5 * truth["weekly_amp"][:, None] * jnp.cos(
        2 * jnp.pi * (day % 7) / 7.0)
    eps = 1.0 + 2.5 * truth["noise"][:, None] * jax.random.normal(
        key, (truth["arr_level"].shape[0], 24))
    return jnp.clip(truth["arr_level"][:, None] * prof * weekly * eps / 24.0
                    * 24.0 / prof.sum() * 24.0, 0.0, None)


def true_ratio(truth, usage):
    return jnp.clip(truth["ratio_a"][:, None]
                    + truth["ratio_b"][:, None]
                    * jnp.log(jnp.clip(usage, 1e-6, None)), 1.05, 3.0)


def synth_params(seed: int, n_clusters: int, pds_per_cluster: int,
                 n_zones: int) -> Dict[str, object]:
    """Synthesize the array-only fleet parameter leaves shared by BOTH
    entry points (sim scenarios and the legacy FleetConfig): latent truth,
    PD power-curve truth, PD usage fractions, stacked zone params, and the
    rollout PRNG key. Pure: identical inputs -> identical arrays."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    n, npds = n_clusters, pds_per_cluster
    truth = cluster_truth(ks[0], n)
    npd = n * npds
    return {
        "key": jax.random.fold_in(key, 17),
        "truth": truth,
        "pd_idle": 60.0 + 40.0 * jax.random.uniform(ks[1], (npd,)),
        "pd_slope": 250.0 + 150.0 * jax.random.uniform(ks[2], (npd,)),
        "pd_curve": 0.8 + 0.5 * jax.random.uniform(ks[3], (npd,)),
        "lam": jax.nn.softmax(jax.random.normal(ks[4], (n, npds)), axis=1),
        "zone": carbon.stack_zone_params(carbon.default_zones(n_zones)),
    }


# ------------------------------------------------------------ state pytrees

class SimParams(NamedTuple):
    """Per-rollout day-cycle parameters. All leaves are arrays; stacking a
    list of SimParams along axis 0 gives the (scenario x seed) batch."""
    key: jnp.ndarray                  # PRNG key data, (2,) uint32
    truth: Dict[str, jnp.ndarray]     # latent cluster processes, (n,)
    pd_idle: jnp.ndarray              # (n*pds,)
    pd_slope: jnp.ndarray             # (n*pds,)
    pd_curve: jnp.ndarray             # (n*pds,)
    lam: jnp.ndarray                  # (n, pds) PD usage fractions
    zone: Dict[str, jnp.ndarray]      # grid-mix params, (z,)
    lambda_e: jnp.ndarray             # () carbon price
    lambda_p: jnp.ndarray             # () peak-power price
    gamma: jnp.ndarray                # () power-capping violation prob
    mobility: jnp.ndarray             # () spatial-shift mobility (0 = off)
    risk_beta: jnp.ndarray            # () CVaR tail fraction (1 = neutral)
    green_scale: jnp.ndarray          # (days, z) solar+wind multiplier
    coal_scale: jnp.ndarray           # (days, z) coal-share multiplier
    cap_scale: jnp.ndarray            # (days, n) capacity multiplier
    arrival_scale: jnp.ndarray        # (days, n) flexible-demand multiplier
    campus_scale: jnp.ndarray         # (days, m) campus power-limit scale
    # Intraday forecast-busting channels (sim.scenarios Intraday*
    # perturbations): hourly multipliers applied to the ACTUALS after the
    # day-ahead forecasts are drawn, so the planner is blind to them
    # until the hours realize. The None default flattens to an empty
    # pytree subtree — absent channels leave every compiled graph
    # byte-identical (same mechanism as StepOut.telemetry).
    arrival_hour_scale: Optional[jnp.ndarray] = None   # (days, 24)
    carbon_hour_scale: Optional[jnp.ndarray] = None    # (days, 24)


class SimState(NamedTuple):
    """Array-only day-cycle state (the scan carry).

    Rescan mode carries the seven rolling ``hist_*`` windows (oldest
    first) and ``pred=None``; streaming mode
    (``StageConfig.streaming=True``) carries the O(1)
    ``stats.PredictorState`` in ``pred``, the ``hist_*`` leaves become
    zero-length stubs (shape (n, 0[, 24]) — dropped from memory, never
    read), and ``carbon_hist`` is truncated to the trailing 7 days the
    carbon forecaster actually consumes."""
    day: jnp.ndarray                  # () int32
    campus: jnp.ndarray               # (n,) int32
    zmap: jnp.ndarray                 # (n,) int32 zone of cluster
    campus_limit: jnp.ndarray         # (m,) kW
    u_pow_cap: jnp.ndarray            # (n,)
    hist_uif: jnp.ndarray             # (n, H, 24)
    hist_flex_daily: jnp.ndarray      # (n, H)
    hist_res_daily: jnp.ndarray       # (n, H)
    hist_usage: jnp.ndarray           # (n, H, 24)
    hist_res: jnp.ndarray             # (n, H, 24)
    hist_tr_pred: jnp.ndarray         # (n, H)
    hist_uif_pred: jnp.ndarray        # (n, H, 24)
    carbon_hist: jnp.ndarray          # (z, H, 24)
    queue: jnp.ndarray                # (n,) shaped-run backlog
    cf_queue: jnp.ndarray             # (n,) counterfactual backlog
    crowded_streak: jnp.ndarray       # (n,) int32
    pause_left: jnp.ndarray           # (n,) int32
    violation_days: jnp.ndarray       # (n,) int32
    observed_days: jnp.ndarray        # (n,) int32
    shaping_allowed: jnp.ndarray      # (n,) bool
    pred: Optional[stats.PredictorState] = None   # streaming carry


class StepOut(NamedTuple):
    """Everything one day produces beyond the carried state. Consumers
    keep what they need (the engine reduces to DayMetrics inside its scan
    body; the legacy ``day_cycle`` records sol/vcc/result) — unused leaves
    are dead-code-eliminated by XLA."""
    res: admission.DayResult          # shaped admission result
    cf: admission.DayResult           # unshaped counterfactual result
    sol: vcc.VCCSolution
    vcc_curve: jnp.ndarray            # (n, 24) post-SLO-gate VCC (with
    #                                   StageConfig.mpc the hour-by-hour
    #                                   ENFORCED curve, not the 00:00 plan)
    fc: Dict[str, jnp.ndarray]        # forecast dict
    prob: vcc.VCCProblem              # problem actually optimized
    eta_act: jnp.ndarray              # (n, 24) actual intensity per cluster
    # DayTelemetry record (sim.telemetry) when StageConfig.telemetry; the
    # default None flattens to an EMPTY pytree subtree, so the legacy
    # (telemetry=False) compiled graph stays byte-identical
    telemetry: Optional[object] = None


@dataclass(frozen=True)
class StageConfig:
    """Static knobs of the staged day cycle (hashable: keys the jit
    cache). Shapes live in the state/params arrays, not here."""
    slo_margin: float = 1.0
    slo_pause_days: int = 7
    joint_spatial: bool = False   # True = joint spatio-temporal optimize
    #                               (spatial.solve_joint: delta and the
    #                               budget shift s descended together);
    #                               False = the paper-mode graph with the
    #                               greedy spatial pre-shift (mobility=0
    #                               makes the shift exactly zero)
    n_members: int = 1            # forecast-ensemble size K (1 = eq. 4
    #                               point-forecast path, graph unchanged;
    #                               K > 1 = CVaR over sampled realizations
    #                               at SimParams.risk_beta — core.risk)
    streaming: bool = False       # True = O(1) streaming prediction layer
    #                               (stats.PredictorState carry instead of
    #                               the (n, H, 24) hist_* rescans); False
    #                               keeps the legacy rescan graph
    #                               byte-identical (golden trace)
    use_pallas: Optional[bool] = None   # VCC PGD kernel dispatch (None=auto)
    interpret: bool = False             # Pallas interpreter (CPU tests)
    telemetry: bool = False       # True = thread a sim.telemetry
    #                               DayTelemetry record (solver
    #                               convergence, forecast calibration,
    #                               SLO/headroom gauges) through the day
    #                               step; False keeps the compiled graph
    #                               byte-identical to the legacy day
    #                               (collapse contract, HLO-tested)
    mpc: bool = False             # True = intra-day MPC recourse: each
    #                               hour observes the realized load /
    #                               intensity and warm-starts a short
    #                               suffix re-solve of the remaining
    #                               hours' VCC (core.mpc); False keeps
    #                               the open-loop day-ahead graph
    #                               byte-identical (collapse contract,
    #                               HLO-tested like `telemetry`)
    slo_allowance: float = 0.25   # late-day arrival fraction NOT counted
    #                               as unmet (admission.finalize_day);
    #                               the default reproduces the historical
    #                               hard-coded 0.25


def pd_truth(params: SimParams) -> power.PDTruth:
    return power.PDTruth(idle_kw=params.pd_idle, slope_kw=params.pd_slope,
                         curve=params.pd_curve)


def roll(hist, new):
    """Drop oldest day, append new. hist (n, H[, 24]); new (n[, 24])."""
    return jnp.concatenate([hist[:, 1:], new[:, None]], axis=1)


# ----------------------------------------------------------------- stages

def carbon_stage(zone: Dict[str, jnp.ndarray], carbon_hist, key,
                 green_scale, coal_scale):
    """Draw one day of actual zone intensity + its day-ahead forecast.

    zone: dict of (z,) grid-mix params; carbon_hist: (z, H, 24);
    green/coal_scale: (z,) scenario multipliers. Returns barrier-pinned
    (act_z (z, 24), fc_z (z, 24))."""
    z = carbon_hist.shape[0]
    zp = dict(zone)
    zp["solar_cap"] = zp["solar_cap"] * green_scale
    zp["wind_cap"] = zp["wind_cap"] * green_scale
    zp["coal_share"] = zp["coal_share"] * coal_scale
    keys = jax.random.split(key, 2 * z)
    act_z = carbon.simulate_zones_from(keys[:z], zp, 1)[:, 0]     # (z, 24)
    fc_z = jax.vmap(carbon.forecast_day_ahead)(
        keys[z:], carbon_hist, act_z, zp["weather_vol"] * 0.15)
    return jax.lax.optimization_barrier((act_z, fc_z))


class PowerModel(NamedTuple):
    """Fitted cluster power model as arrays (the power_stage output)."""
    coef: jnp.ndarray       # (n*pds, K+2) piecewise-linear coefficients
    breaks: jnp.ndarray     # (n*pds, K) hinge locations
    lam: jnp.ndarray        # (n, pds) PD usage fractions
    cap_pd: jnp.ndarray     # (n*pds,) cluster capacity per PD row


def power_stage(hist_usage, lam, capacity, pdt: power.PDTruth, key
                ) -> PowerModel:
    """Fit PD piecewise power models on recent cluster usage history.

    hist_usage: (n, hist, 24); lam: (n, pds); capacity: (n,);
    pdt: power.PDTruth with (n*pds,) fields. jit/vmap-safe.
    """
    n, npd = lam.shape
    u_cl = hist_usage[:, -28:].reshape(n, -1)                # (n, t)
    u_pd = (lam[..., None] * u_cl[:, None, :]).reshape(n * npd, -1)
    u_norm = u_pd / jnp.clip(
        capacity[:, None, None].repeat(npd, 1).reshape(n * npd, 1),
        1e-6, None)
    p_pd = power.simulate_pd_power(key, pdt, u_norm)
    coef, breaks = power.fit_pd_models(u_norm, p_pd)
    # materialization point: keeps the fitted model's numerics independent
    # of how downstream consumers fuse (bitwise batched/sequential parity)
    coef, breaks = jax.lax.optimization_barrier((coef, breaks))
    cap_pd = capacity[:, None].repeat(npd, 1).reshape(-1)
    return PowerModel(coef=coef, breaks=breaks, lam=lam, cap_pd=cap_pd)


def model_power(m: PowerModel, u_cluster):
    """Cluster power at cluster CPU usage. (n,) -> (n,) kW."""
    n, npd = m.lam.shape
    u_pd_now = (m.lam * u_cluster[:, None]).reshape(-1)
    u_n = u_pd_now / jnp.clip(m.cap_pd, 1e-6, None)
    p = jax.vmap(power.pd_power)(m.coef, m.breaks, u_n[:, None])[:, 0]
    return p.reshape(n, npd).sum(axis=1)


def model_slope(m: PowerModel, u_cluster):
    """Local cluster slope d kW / d cluster-CPU. (n,) -> (n,)."""
    n, npd = m.lam.shape
    u_pd_now = (m.lam * u_cluster[:, None]).reshape(-1)
    u_n = u_pd_now / jnp.clip(m.cap_pd, 1e-6, None)
    s = jax.vmap(power.pd_slope)(m.coef, m.breaks, u_n[:, None])[:, 0]
    s = s / jnp.clip(m.cap_pd, 1e-6, None)
    return (s.reshape(n, npd) * m.lam).sum(axis=1)


def forecast_stage(hist_uif, hist_flex_daily, hist_res_daily, hist_usage,
                   hist_res, hist_tr_pred, hist_uif_pred, day, gamma):
    """Next-day forecasting pipeline from rolling history arrays.

    All (n, hist[, 24]); day/gamma may be traced. Returns the
    barrier-pinned forecast dict consumed by optimize_stage."""
    n = hist_uif.shape[0]
    dow = jnp.asarray(day % 7)
    uif_pred = jax.vmap(lambda h: forecast.forecast_inflexible(h, dow))(
        hist_uif)
    tuf_pred = jax.vmap(lambda d: forecast.forecast_daily_total(d, dow))(
        hist_flex_daily)
    tr_pred = jax.vmap(lambda d: forecast.forecast_daily_total(d, dow))(
        hist_res_daily)
    ra, rb = jax.vmap(forecast.fit_ratio_model)(
        hist_usage[:, -28:].reshape(n, -1),
        hist_res[:, -28:].reshape(n, -1))
    eps97 = jax.vmap(lambda p, a: forecast.relative_error_quantile(
        p[-90:], a[-90:], 0.97))(hist_tr_pred, hist_res_daily)
    theta = forecast.theta_requirement(tr_pred, eps97)
    alpha = jax.vmap(forecast.alpha_inflation)(theta, uif_pred, tuf_pred,
                                               ra, rb)
    # (1-gamma) hourly inflexible quantile from trailing prediction errors
    epsq = jax.vmap(lambda p, a: forecast.relative_error_quantile(
        p[-28:].reshape(-1), a[-28:].reshape(-1), 1 - gamma))(
        hist_uif_pred, hist_uif)
    uif_q = uif_pred * (1.0 + jnp.clip(epsq, 0.0, 1.0)[:, None])
    fc = {"uif": uif_pred, "tuf": tuf_pred, "tr": tr_pred,
          "ratio_a": ra, "ratio_b": rb, "theta": theta, "alpha": alpha,
          "uif_q": uif_q}
    return jax.lax.optimization_barrier(fc)


def forecast_stage_streaming(pred: stats.PredictorState, day, gamma):
    """O(1) streaming counterpart of ``forecast_stage``: the same
    barrier-pinned forecast dict from the ``stats.PredictorState`` carry
    instead of rescanning the (n, H, 24) history windows."""
    return jax.lax.optimization_barrier(
        stats.streaming_forecast(pred, day, gamma))


def build_problem_arrays(fc, eta_fc, power_fn, slope_fn, queue, u_pow_cap,
                         capacity, campus, campus_limit, lambda_e, lambda_p
                         ) -> vcc.VCCProblem:
    """Assemble the fleetwide VCC problem from the forecast dict + carbon
    forecast + structural arrays (risk-aware budget, eq. 3)."""
    # risk-aware daily flexible budget (eq. 3) + carried-over queue
    tau = fc["alpha"] * fc["tuf"] + queue
    u_nom = fc["uif"] + tau[:, None] / 24.0
    pow_nom = jax.vmap(power_fn, in_axes=1, out_axes=1)(u_nom)
    pi = jax.vmap(slope_fn, in_axes=1, out_axes=1)(u_nom)
    ratio = forecast.ratio_at(fc["ratio_a"][:, None], fc["ratio_b"][:, None],
                              u_nom)
    return vcc.VCCProblem(
        eta=eta_fc, u_if=fc["uif"], u_if_q=fc["uif_q"], tau=tau,
        pow_nom=pow_nom, pi=pi, u_pow_cap=u_pow_cap,
        capacity=capacity, ratio=ratio, campus=campus,
        campus_limit=campus_limit, lambda_e=lambda_e, lambda_p=lambda_p)


def optimize_stage(cfg: StageConfig, fc, eta_fc, model: PowerModel, queue,
                   u_pow_cap, cap_day, campus, campus_limit, lambda_e,
                   lambda_p, mobility, ens: Optional[Dict] = None):
    """Fleetwide risk-aware VCC optimization. The PGD machinery is the
    ``core.solver`` layer throughout; kernels dispatch per
    cfg.use_pallas/interpret.

    Spatial flexibility (two statically selected graphs, keyed by
    ``cfg.joint_spatial``):

    * False (default) — the greedy spatial pre-shift runs before the
      temporal solve; ``mobility == 0`` collapses the shift to exactly
      zero, keeping that path bitwise-identical to the pre-joint day
      cycle (golden-trace + parity contract; the trace's scenarios are
      all mobility=0). For ``mobility > 0`` the pre-shift is now the
      EXACT linear minimizer (``spatial.spatial_shift``) rather than a
      truncated PGD loop — an intentional result change for
      spatial-mobility scenarios.
    * True — ``spatial.solve_joint``: the temporal deviations and the
      daily budget shift are descended TOGETHER (bounds recomputed from
      the shifted budgets inside the fused step), warm-started from and
      never worse than the sequential two-phase answer.

    ``ens`` (the ``risk.day_ensembles`` dict, present iff cfg.n_members
    > 1) attaches K forecast realizations AFTER the budgets are placed:
    the temporal solve then descends the soft-CVaR member tilt instead of
    the point-forecast objective (under ``joint_spatial`` the joint solve
    places the budgets on the point forecast, then the CVaR solve shapes
    at the shifted budgets). With ens=None and joint_spatial=False this
    graph is IDENTICAL to the pre-ensemble day cycle.

    Returns ``(prob, sol, diag)``: ``diag`` is the solver-telemetry dict
    (``vcc.solve_vcc(..., telemetry=True)`` channels + ``joint_winner``)
    when ``cfg.telemetry``, else ``None`` — and the telemetry=False path
    calls the solvers EXACTLY as before (byte-identical graph)."""
    prob = build_problem_arrays(
        fc, eta_fc,
        lambda u: model_power(model, u), lambda u: model_slope(model, u),
        queue, u_pow_cap, cap_day, campus, campus_limit, lambda_e, lambda_p)
    prob = jax.lax.optimization_barrier(prob)
    diag = None
    if cfg.joint_spatial:
        if cfg.telemetry:
            sol, tau_j, _, diag = spatial.solve_joint(
                prob, mobility, use_pallas=cfg.use_pallas,
                interpret=cfg.interpret, telemetry=True)
        else:
            sol, tau_j, _ = spatial.solve_joint(prob, mobility,
                                                use_pallas=cfg.use_pallas,
                                                interpret=cfg.interpret)
        sol, tau_j = jax.lax.optimization_barrier((sol, tau_j))
        prob = dataclasses.replace(prob, tau=tau_j)
        if ens is not None:
            prob = risk.attach_ensemble(prob, **ens)
            if cfg.telemetry:
                # the CVaR solve at the shifted budgets produces the final
                # delta: report ITS convergence, keep the joint verdict
                sol, diag2 = vcc.solve_vcc(prob, use_pallas=cfg.use_pallas,
                                           interpret=cfg.interpret,
                                           telemetry=True)
                diag = {**diag2, "joint_winner": diag["joint_winner"]}
            else:
                sol = vcc.solve_vcc(prob, use_pallas=cfg.use_pallas,
                                    interpret=cfg.interpret)
        if diag is not None:
            diag = jax.lax.optimization_barrier(diag)
        return prob, sol, diag
    tau_shifted, _ = spatial.spatial_shift(prob, mobility=mobility)
    tau_shifted = jax.lax.optimization_barrier(tau_shifted)
    prob = dataclasses.replace(prob, tau=tau_shifted)
    if ens is not None:
        prob = risk.attach_ensemble(prob, **ens)
    if cfg.telemetry:
        sol, diag = vcc.solve_vcc(prob, use_pallas=cfg.use_pallas,
                                  interpret=cfg.interpret, telemetry=True)
        # the sequential path never runs the joint refinement: report the
        # degenerate 0.0 so the telemetry pytree is config-independent
        diag["joint_winner"] = jnp.zeros((), f32)
        diag = jax.lax.optimization_barrier(diag)
    else:
        sol = vcc.solve_vcc(prob, use_pallas=cfg.use_pallas,
                            interpret=cfg.interpret)
    return prob, sol, diag


def barrier_result(res: admission.DayResult) -> admission.DayResult:
    """Pin a DayResult as an XLA materialization point. Without it, XLA
    fuses admission outputs into downstream consumers, and the fusion plan
    (hence float rounding) shifts with batch extent — breaking bitwise
    batched-vs-sequential parity. Field order mirrors the dataclass."""
    vals = jax.lax.optimization_barrier(
        (res.usage_flex, res.usage_total, res.reservations, res.power,
         res.carbon, res.served, res.arrived, res.queue_end, res.unmet))
    return admission.DayResult(*vals)


def sample_day_truth(truth, day, day_key, cap_day, arr_scale,
                     arr_hour_scale=None):
    """Sample the day's actual load: (u_if, arrivals, ratio_true), pinned.

    ``arr_hour_scale`` (optional (24,)): intraday forecast-busting
    multiplier on arrivals — applied to the ACTUALS only, after the
    forecasts were issued. None (the default) traces the exact legacy op
    sequence (byte-identical compiled graph)."""
    u_if = sample_inflexible(jax.random.fold_in(day_key, 2), truth, day)
    u_if = jnp.minimum(u_if, 0.98 * cap_day[:, None])   # outage derates
    arrivals = sample_arrivals(jax.random.fold_in(day_key, 3), truth, day)
    arrivals = arrivals * arr_scale[:, None]
    if arr_hour_scale is not None:
        arrivals = arrivals * arr_hour_scale[None, :]
    ratio_true = true_ratio(truth, u_if + arrivals)
    # pin the sampled truth: its elementwise chain must not re-fuse (and
    # re-round) differently between the scan body and other contexts
    return jax.lax.optimization_barrier((u_if, arrivals, ratio_true))


def observe_stage(truth, day, day_key, vcc_curve, cap_day, arr_scale,
                  queue, cf_queue, power_fn, intensity,
                  allowance_frac: float = 0.25, arr_hour_scale=None):
    """Sample the day's true load and run shaped + counterfactual
    admission. Returns (shaped DayResult, counterfactual DayResult,
    u_if, arrivals), results barrier-pinned."""
    u_if, arrivals, ratio_true = sample_day_truth(
        truth, day, day_key, cap_day, arr_scale, arr_hour_scale)
    res = admission.run_day(vcc_curve, u_if, arrivals, ratio_true, cap_day,
                            queue, power_fn, intensity, allowance_frac)
    unshaped = jnp.broadcast_to(cap_day[:, None] * 10.0, vcc_curve.shape)
    cf = admission.run_day(unshaped, u_if, arrivals, ratio_true, cap_day,
                           cf_queue, power_fn, intensity, allowance_frac)
    return barrier_result(res), barrier_result(cf), u_if, arrivals


def observe_stage_mpc(truth, day, day_key, prob, sol, fc, gate, cap_day,
                      arr_scale, queue, cf_queue, power_fn, intensity,
                      allowance_frac: float = 0.25, arr_hour_scale=None,
                      use_pallas=None, interpret=False):
    """Closed-loop counterpart of ``observe_stage``: same sampled truth
    and same unshaped counterfactual, but the shaped run is the hourly
    MPC recourse loop (``core.mpc.mpc_day``) instead of open-loop
    admission under the 00:00 curve. Returns (res, cf, u_if, arrivals,
    enforced_vcc (n, 24), stats.HourAccum, mpc.MPCDiag)."""
    u_if, arrivals, ratio_true = sample_day_truth(
        truth, day, day_key, cap_day, arr_scale, arr_hour_scale)
    res, vcc_real, acc, diag = mpc.mpc_day(
        prob, sol, fc["tuf"], gate, cap_day, u_if, arrivals, ratio_true,
        queue, power_fn, intensity, allowance_frac=allowance_frac,
        use_pallas=use_pallas, interpret=interpret)
    unshaped = jnp.broadcast_to(cap_day[:, None] * 10.0, vcc_real.shape)
    cf = admission.run_day(unshaped, u_if, arrivals, ratio_true, cap_day,
                           cf_queue, power_fn, intensity, allowance_frac)
    vcc_real = jax.lax.optimization_barrier(vcc_real)
    return (barrier_result(res), barrier_result(cf), u_if, arrivals,
            vcc_real, acc, diag)


def slo_stage(slo_state, slo_cfg: slo.SLOConfig, daily_reservations,
              vcc_budget, unmet, arrived):
    """End-of-day SLO feedback: returns (new slo_state, shaping_allowed
    for the NEXT day). ``arrived`` scales the violation threshold
    (slo.SLOConfig.rel_tol)."""
    return slo.update(slo_state, slo_cfg, daily_reservations, vcc_budget,
                      unmet, arrived)


# ------------------------------------------------------------- composition

def make_day_step(cfg: StageConfig):
    """One pure CICS day: forecast -> optimize -> shape -> observe -> SLO.

    Returns step(params, state, xs) -> (state', StepOut) where xs holds
    this day's scenario-schedule slices (all-ones = the paper's nominal
    operation, which is what the legacy fleet path uses)."""
    slo_cfg = slo.SLOConfig(margin=cfg.slo_margin,
                            pause_days=cfg.slo_pause_days)
    if cfg.streaming and cfg.n_members > 1:
        raise ValueError(
            "StageConfig.streaming=True does not support forecast "
            "ensembles (n_members > 1): risk.day_ensembles bootstraps "
            "whole days of the hist_uif_pred/hist_uif error history, "
            "which the streaming state no longer carries")

    def step(params: SimParams, state: SimState, xs: Dict[str, jnp.ndarray]
             ) -> Tuple[SimState, StepOut]:
        day_key = jax.random.fold_in(params.key, state.day)
        cap_day = jax.lax.optimization_barrier(
            params.truth["capacity"] * xs["cap_scale"])
        # 1-2. power pipeline + load forecasting. Streaming: O(1) updates
        # over the PredictorState carry (the usage ring IS the 28-day
        # window the rescan power fit slices, so the fit is bitwise the
        # same); rescan: the legacy O(H) history-window graph.
        if cfg.streaming:
            model = power_stage(state.pred.usage_ring, params.lam,
                                params.truth["capacity"], pd_truth(params),
                                jax.random.fold_in(day_key, 1))
            fc = forecast_stage_streaming(state.pred, state.day,
                                          params.gamma)
        else:
            model = power_stage(state.hist_usage, params.lam,
                                params.truth["capacity"], pd_truth(params),
                                jax.random.fold_in(day_key, 1))
            fc = forecast_stage(
                state.hist_uif, state.hist_flex_daily, state.hist_res_daily,
                state.hist_usage, state.hist_res, state.hist_tr_pred,
                state.hist_uif_pred, state.day, params.gamma)
        # 3. carbon pipeline: scenario-perturbed grid, day-ahead forecast
        act_z, fc_z = carbon_stage(params.zone, state.carbon_hist,
                                   jax.random.fold_in(day_key, 4),
                                   xs["green_scale"], xs["coal_scale"])
        # intraday forecast-busting: perturb the ACTUAL intensity after
        # the day-ahead forecast is drawn (the planner is blind until the
        # hours realize; tomorrow's forecaster sees them via carbon_hist)
        if "carbon_hour_scale" in xs:
            act_z = act_z * xs["carbon_hour_scale"][None, :]
        eta_act = act_z[state.zmap]
        eta_fc = fc_z[state.zmap]
        # 3b. forecast ensembles (K > 1 only: the n_members == 1 graph must
        # stay identical to the point-forecast day — parity/golden traces)
        ens = None
        if cfg.n_members > 1:
            ens = risk.day_ensembles(
                jax.random.fold_in(day_key, 5), cfg.n_members, fc["uif"],
                state.hist_uif_pred, state.hist_uif, fc_z,
                state.carbon_hist, state.zmap, params.risk_beta)
        # 4. fleetwide risk-aware VCC optimization (+ spatial pre-shift)
        prob, sol, sdiag = optimize_stage(
            cfg, fc, eta_fc, model, state.queue,
            state.u_pow_cap * xs["cap_scale"], cap_day, state.campus,
            state.campus_limit * xs["campus_scale"],
            params.lambda_e, params.lambda_p, params.mobility, ens=ens)
        # 5. SLO gate: paused clusters get VCC = machine capacity
        gate = state.shaping_allowed & sol.shaped
        vcc_curve = jnp.where(gate[:, None], sol.vcc, cap_day[:, None] * 10.0)
        vcc_curve = jax.lax.optimization_barrier(vcc_curve)
        # 6. real time: admission on ACTUAL load (+ counterfactual).
        # mpc=True runs the hourly recourse loop and the curve the SLO
        # detector sees is the hour-by-hour ENFORCED one, not the 00:00
        # plan; mpc=False traces the exact open-loop legacy graph.
        arr_hs = xs.get("arrival_hour_scale")
        mdiag = None
        acc = None
        if cfg.mpc:
            res, cf, u_if, _, vcc_enforced, acc, mdiag = observe_stage_mpc(
                params.truth, state.day, day_key, prob, sol, fc, gate,
                cap_day, xs["arrival_scale"], state.queue, state.cf_queue,
                lambda u: model_power(model, u), eta_act,
                allowance_frac=cfg.slo_allowance, arr_hour_scale=arr_hs,
                use_pallas=cfg.use_pallas, interpret=cfg.interpret)
        else:
            res, cf, u_if, _ = observe_stage(
                params.truth, state.day, day_key, vcc_curve, cap_day,
                xs["arrival_scale"], state.queue, state.cf_queue,
                lambda u: model_power(model, u), eta_act,
                allowance_frac=cfg.slo_allowance, arr_hour_scale=arr_hs)
            vcc_enforced = vcc_curve
        # 7. telemetry + SLO feedback
        slo_state = {"crowded_streak": state.crowded_streak,
                     "pause_left": state.pause_left,
                     "violation_days": state.violation_days,
                     "observed_days": state.observed_days}
        new_slo, allowed = slo_stage(slo_state, slo_cfg,
                                     hour_sum(res.reservations),
                                     hour_sum(vcc_enforced), res.unmet,
                                     res.arrived)
        if cfg.streaming:
            # O(1) telemetry: absorb the day into the streaming carry
            # (prediction errors pair same-day with the fc issued above —
            # exactly what the hist_*_pred rolls recorded for later)
            if cfg.mpc:
                # hour-grain chain: the 24 hour_update scatters finalize
                # into the same PredictorState the daily batch would
                pred_new = stats.hour_finalize(state.pred, acc, fc,
                                               state.day, params.gamma)
            else:
                pred_new = stats.predictor_update(
                    state.pred, fc, state.day, params.gamma, u_if,
                    res.served, hour_sum(res.reservations),
                    res.usage_total, res.reservations)
            telemetry = dict(pred=pred_new)
        else:
            # roll the rescan history windows (predictions included, for
            # the trailing-error quantiles)
            telemetry = dict(
                hist_uif=roll(state.hist_uif, u_if),
                hist_flex_daily=roll(state.hist_flex_daily, res.served),
                hist_res_daily=roll(state.hist_res_daily,
                                    hour_sum(res.reservations)),
                hist_usage=roll(state.hist_usage, res.usage_total),
                hist_res=roll(state.hist_res, res.reservations),
                hist_tr_pred=roll(state.hist_tr_pred, fc["tr"]),
                hist_uif_pred=roll(state.hist_uif_pred, fc["uif"]))
        new_state = state._replace(
            day=state.day + 1,
            carbon_hist=roll(state.carbon_hist, act_z),
            queue=res.queue_end,
            cf_queue=cf.queue_end,
            crowded_streak=new_slo["crowded_streak"],
            pause_left=new_slo["pause_left"],
            violation_days=new_slo["violation_days"],
            observed_days=new_slo["observed_days"],
            shaping_allowed=allowed,
            **telemetry,
        )
        # 8. DayTelemetry record (telemetry=False leaves the default None
        # StepOut leaf -> empty pytree subtree -> unchanged compiled graph)
        telem = None
        if cfg.telemetry:
            # lazy: core must not import repro.sim at module level
            from repro.sim import telemetry as _telemetry
            if cfg.streaming:
                trail = {"uif": state.pred.uif_day_ring,
                         "tuf": state.pred.flex_ring,
                         "tr": state.pred.res_ring}
            else:
                trail = {"uif": hour_sum(state.hist_uif[:, -7:]),
                         "tuf": state.hist_flex_daily[:, -7:],
                         "tr": state.hist_res_daily[:, -7:]}
            telem = _telemetry.day_telemetry(
                sdiag, fc, res, u_if, vcc_enforced,
                pause_left=new_slo["pause_left"], shaped=sol.shaped,
                trail=trail, recourse=mdiag)
        return new_state, StepOut(res=res, cf=cf, sol=sol,
                                  vcc_curve=vcc_enforced, fc=fc, prob=prob,
                                  eta_act=eta_act, telemetry=telem)

    return step


@functools.lru_cache(maxsize=None)
def jitted_day_step(cfg: StageConfig):
    """The SAME jitted executable for every standalone driver of the day
    cycle (legacy fleet.day_cycle, sequential debugging, parity tests) —
    one compile per StageConfig, bitwise-identical results across callers."""
    return jax.jit(make_day_step(cfg))


def ones_xs(n_clusters: int, n_campuses: int, n_zones: int
            ) -> Dict[str, jnp.ndarray]:
    """Neutral (nominal-operation) scenario slices for one day."""
    return {"green_scale": jnp.ones((n_zones,), f32),
            "coal_scale": jnp.ones((n_zones,), f32),
            "cap_scale": jnp.ones((n_clusters,), f32),
            "arrival_scale": jnp.ones((n_clusters,), f32),
            "campus_scale": jnp.ones((n_campuses,), f32)}


# ------------------------------------------------------------ init/burn-in

def burnin_step(params: SimParams, state: SimState) -> SimState:
    """One unshaped day with the cheap linear power proxy (history fill)."""
    day_key = jax.random.fold_in(params.key, state.day)
    cap = params.truth["capacity"]

    def proxy_power(u):
        return 100.0 + 300.0 * u

    act_z, _ = carbon_stage(params.zone, state.carbon_hist,
                            jax.random.fold_in(day_key, 4),
                            jnp.ones_like(params.zone["solar_cap"]),
                            jnp.ones_like(params.zone["solar_cap"]))
    unshaped = jnp.broadcast_to(cap[:, None] * 10.0, (cap.shape[0], 24))
    res, _, u_if, _ = observe_stage(
        params.truth, state.day, day_key, unshaped, cap,
        jnp.ones_like(cap), state.queue, state.queue, proxy_power,
        act_z[state.zmap])
    return state._replace(
        day=state.day + 1,
        hist_uif=roll(state.hist_uif, u_if),
        hist_flex_daily=roll(state.hist_flex_daily, res.served),
        hist_res_daily=roll(state.hist_res_daily,
                            hour_sum(res.reservations)),
        hist_usage=roll(state.hist_usage, res.usage_total),
        hist_res=roll(state.hist_res, res.reservations),
        carbon_hist=roll(state.carbon_hist, act_z),
        queue=res.queue_end,
        cf_queue=res.queue_end,
    )


def make_init(n_clusters: int, n_campuses: int, n_zones: int,
              hist_days: int, streaming: bool = False):
    """init(params) -> burned-in SimState. jit- and vmap-compatible: the
    hist_days burn-in runs under lax.scan (one dispatch, not hundreds).

    With ``streaming=True`` the burn-in still fills the full history
    window (it is one-time cost), then every streaming estimator is
    warm-started from it (``stats.init_predictor`` — handoff-bitwise on
    the EWMA components) and the seven ``hist_*`` windows are dropped to
    zero-length stubs: the carried state becomes O(1) in hist_days."""
    n, m, z, H = n_clusters, n_campuses, n_zones, hist_days
    if streaming and H < 7:
        raise ValueError(f"streaming init needs hist_days >= 7, got {H}")
    campus_np = [i % m for i in range(n)]
    zmap_np = [(c % z) for c in campus_np]

    def init(params: SimParams) -> SimState:
        cap = params.truth["capacity"]
        state = SimState(
            day=jnp.zeros((), jnp.int32),
            campus=jnp.asarray(campus_np, jnp.int32),
            zmap=jnp.asarray(zmap_np, jnp.int32),
            campus_limit=jnp.zeros((m,), f32),
            u_pow_cap=cap * 0.95,
            hist_uif=jnp.zeros((n, H, 24), f32),
            hist_flex_daily=jnp.zeros((n, H), f32),
            hist_res_daily=jnp.zeros((n, H), f32),
            hist_usage=jnp.zeros((n, H, 24), f32),
            hist_res=jnp.zeros((n, H, 24), f32),
            hist_tr_pred=jnp.zeros((n, H), f32),
            hist_uif_pred=jnp.zeros((n, H, 24), f32),
            carbon_hist=jnp.zeros((z, H, 24), f32),
            queue=jnp.zeros((n,), f32),
            cf_queue=jnp.zeros((n,), f32),
            crowded_streak=jnp.zeros((n,), jnp.int32),
            pause_left=jnp.zeros((n,), jnp.int32),
            violation_days=jnp.zeros((n,), jnp.int32),
            observed_days=jnp.zeros((n,), jnp.int32),
            shaping_allowed=jnp.ones((n,), bool),
        )

        def burn(s, _):
            return burnin_step(params, s), None

        state, _ = jax.lax.scan(burn, state, None, length=H)
        # zero-error prediction prior; honest quantiles build up in-horizon
        state = state._replace(hist_tr_pred=state.hist_res_daily,
                               hist_uif_pred=state.hist_uif)
        # campus contracts: 97% of fitted-model campus peak over last week
        model = power_stage(state.hist_usage, params.lam, cap,
                            pd_truth(params),
                            jax.random.fold_in(params.key, 999))
        upow = jax.vmap(lambda u: model_power(model, u),
                        in_axes=1, out_axes=1)(
            state.hist_usage[:, -7:].reshape(n, -1))
        peak = upow.max(axis=1)
        limit = jax.ops.segment_sum(peak, state.campus,
                                    num_segments=m) * 0.97
        state = state._replace(campus_limit=limit.astype(f32))
        if streaming:
            pred = stats.init_predictor(
                state.hist_uif, state.hist_flex_daily,
                state.hist_res_daily, state.hist_usage, state.hist_res,
                state.hist_tr_pred, state.hist_uif_pred, state.day,
                params.gamma)
            state = state._replace(
                pred=pred,
                # carbon_stage's day-ahead forecast reads only the
                # trailing 7 days (carbon.forecast_day_ahead), so the
                # streaming carry keeps exactly that window — bitwise
                # the same forecasts, O(1) state in hist_days
                carbon_hist=state.carbon_hist[:, -stats.WEEK:],
                hist_uif=jnp.zeros((n, 0, 24), f32),
                hist_flex_daily=jnp.zeros((n, 0), f32),
                hist_res_daily=jnp.zeros((n, 0), f32),
                hist_usage=jnp.zeros((n, 0, 24), f32),
                hist_res=jnp.zeros((n, 0, 24), f32),
                hist_tr_pred=jnp.zeros((n, 0), f32),
                hist_uif_pred=jnp.zeros((n, 0, 24), f32))
        # materialize: burned-in state must not fuse into rollout consumers
        # (jit(init + rollout) would otherwise drift vs separate calls)
        return jax.lax.optimization_barrier(state)

    return init
