"""Streaming sufficient statistics for the prediction layer.

The rescan prediction pipeline (``stages.forecast_stage`` /
``stages.power_stage``) carries seven full rolling-history arrays
``(n, H, 24)`` in ``SimState`` and rescans them every day, so day-step
cost and state memory grow with the history length H. This module owns
the O(1)-per-day replacement: first-class incremental estimators carried
as one pytree, ``PredictorState``, sized O(n * 24)-ish INDEPENDENT of H.

Estimators
----------
* **EWMA levels** — weekly mean, hour-of-week and day-of-week factor
  levels. The carried recursion is EXACTLY ``forecast.ewma``'s step
  (``forecast.ewma_update`` with ``forecast.ewma_alpha``): applying the
  incremental update T times from ``x[0]`` equals the batch scan bitwise
  (property-tested). The weekly-mean level updates daily on the trailing
  7-day mean with the half-life converted to days
  (``WMEAN_HL_DAYS = 7 * 0.5``); each hour/day-of-week factor slot
  updates once per week at the rescan's weekly half-life — the same
  cadence the rescan's week-folded scan applies.
* **Exponentially-weighted regression moments** — the previous-day
  deviation corrector (through-origin coef, mirroring
  ``forecast.deviation_coef`` on dow-factored deviations) and the
  ``R(h) = a + b log u`` reservations-to-usage model. Daily decay
  half-lives are chosen so the effective sample size matches the rescan
  windows (8 days for the corrector, 28 days for the ratio fit).
* **Exact ring buffers** — kept ONLY where a windowed statistic
  genuinely needs the window: trailing scalar prediction-error rings for
  the Theta 97%-quantile (eq. 2, 90 days) and the (1-gamma) power-capping
  quantile (28 days, compressed to one scalar per day), plus a 28-day
  usage ring for the PD piecewise-power refits — the breakpoints are
  window quantiles of usage, so ``stages.power_stage`` over the ring is
  bitwise-identical to the rescan's ``hist_usage[:, -28:]`` fit (the
  ring IS that slice), normal equations and all.

Equivalence contract (tested in tests/test_streaming.py)
--------------------------------------------------------
``init_predictor`` warm-starts every estimator from a burned-in history
window using the SAME rescan functions, so at the handoff day the
streaming forecasts of the EWMA components (``uif``/``tuf``/``tr``,
hence ``theta``) match the rescan bitwise; the ratio/alpha terms match
to float tolerance (moment-form vs centered-form least squares). From
there the two paths are different estimators of the same quantities —
the rescan re-partitions a sliding H-window into weeks each day, which
has no O(1) update — and a >=14-day dual run pins their drift to a
documented tolerance (also CI-gated in benchmarks/sim_bench.py).
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import forecast

f32 = jnp.float32

# rescan window sizes mirrored by the exact rings
THETA_WINDOW = 90            # eq. 2: 97%-quantile of daily T_R errors
GAMMA_WINDOW = 28            # (1-gamma) quantile of hourly U_IF errors
USAGE_WINDOW = 28            # PD power refits + breakpoint quantiles
WEEK = 7

# daily-update half-lives of the EW estimators. The weekly-mean level
# converts the rescan's 0.5-week half-life to update steps of one day;
# the regression moments match the rescan windows' effective sample
# size: a daily decay rho has ESS (1+rho)/(1-rho), so ESS=8 (corrector)
# -> rho=7/9 -> hl ~ 2.76 d, ESS=28 (ratio fit) -> rho=27/29 -> ~9.7 d.
WMEAN_HL_DAYS = 7.0 * 0.5
DEV_HL_DAYS = 2.76
RATIO_HL_DAYS = 9.7


def decay_from_half_life(half_life_days: float) -> jnp.ndarray:
    """Per-day retention factor rho = 0.5 ** (1 / half_life)."""
    return jnp.exp(jnp.log(0.5) / jnp.maximum(half_life_days, 1e-3))


# -------------------------------------------------------------- primitives

def ring_push(ring: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Drop the oldest entry along axis 1, append ``x`` (chronological
    order — oldest first, like the rescan history arrays)."""
    return jnp.concatenate([ring[:, 1:], x[:, None]], axis=1)


def ring_quantile(ring: jnp.ndarray, q) -> jnp.ndarray:
    """q-quantile over the window axis (axis 1). Exact — the ring holds
    the raw trailing values, not a sketch."""
    return jnp.quantile(ring, q, axis=1)


class EWMoments(NamedTuple):
    """Exponentially-weighted simple-regression moments of (x, y) sample
    batches: y ~ a + b x via the normal equations in moment form. All
    leaves (n,)."""
    w: jnp.ndarray               # decayed sample count
    sx: jnp.ndarray              # sum x
    sy: jnp.ndarray              # sum y
    sxx: jnp.ndarray             # sum x^2
    sxy: jnp.ndarray             # sum x y


def ew_init(x: jnp.ndarray, y: jnp.ndarray) -> EWMoments:
    """Unweighted moments of an initial sample batch. x, y: (n, t)."""
    return EWMoments(
        w=jnp.full(x.shape[:1], float(x.shape[1]), f32),
        sx=jnp.sum(x, axis=1), sy=jnp.sum(y, axis=1),
        sxx=jnp.sum(x * x, axis=1), sxy=jnp.sum(x * y, axis=1))


def ew_update(m: EWMoments, x: jnp.ndarray, y: jnp.ndarray, rho
              ) -> EWMoments:
    """Decay by ``rho`` then absorb one day's sample batch. x, y: (n, t)."""
    t = float(x.shape[1])
    return EWMoments(
        w=rho * m.w + t,
        sx=rho * m.sx + jnp.sum(x, axis=1),
        sy=rho * m.sy + jnp.sum(y, axis=1),
        sxx=rho * m.sxx + jnp.sum(x * x, axis=1),
        sxy=rho * m.sxy + jnp.sum(x * y, axis=1))


def ew_linfit(m: EWMoments):
    """(a, b) of y ~ a + b x from the moments (normal equations)."""
    xm = m.sx / jnp.clip(m.w, 1e-9, None)
    ym = m.sy / jnp.clip(m.w, 1e-9, None)
    b = (m.sxy - m.sx * ym) / jnp.clip(m.sxx - m.sx * xm, 1e-9, None)
    return ym - b * xm, b


class DevMoments(NamedTuple):
    """EW moments of the previous-day deviation corrector: next-day
    deviation ~ coef * previous-day deviation (through the origin,
    mirroring ``forecast.deviation_coef``). All leaves (n,)."""
    sxx: jnp.ndarray
    sxy: jnp.ndarray
    prev: jnp.ndarray            # yesterday's deviation (today's x)


def dev_init(dev: jnp.ndarray) -> DevMoments:
    """Moments from an initial deviation series. dev: (n, t), oldest
    first — the same (x, y) = (dev[:-1], dev[1:]) pairing and sum order
    as ``forecast.deviation_coef`` (bitwise at the handoff)."""
    x, y = dev[:, :-1], dev[:, 1:]
    return DevMoments(sxx=jnp.sum(x * x, axis=1),
                      sxy=jnp.sum(x * y, axis=1), prev=dev[:, -1])


def dev_update(m: DevMoments, dev_today: jnp.ndarray, rho) -> DevMoments:
    """Decay, absorb the (yesterday, today) deviation pair, carry today."""
    return DevMoments(sxx=rho * m.sxx + m.prev * m.prev,
                      sxy=rho * m.sxy + m.prev * dev_today,
                      prev=dev_today)


def dev_coef(m: DevMoments) -> jnp.ndarray:
    """clip(Sxy / Sxx, -1, 1) — ``forecast.deviation_coef``'s estimate."""
    return jnp.clip(m.sxy / jnp.clip(m.sxx, 1e-9, None), -1.0, 1.0)


# ---------------------------------------------------------- PredictorState

class PredictorState(NamedTuple):
    """The streaming prediction layer's entire carry: O(n) in the fleet,
    O(1) in the history length. Week rings are day-of-week indexed (slot
    d%7 holds the most recent day with that dow — together the trailing
    7 days); error/usage rings are chronological (oldest first)."""
    # inflexible hourly usage U_IF
    uif_day_ring: jnp.ndarray    # (n, 7) trailing daily means, dow slots
    uif_prev: jnp.ndarray        # (n, 24) yesterday's hourly actuals
    uif_wmean: jnp.ndarray       # (n,) weekly-mean EWMA level
    uif_how: jnp.ndarray         # (n, 7, 24) hour-of-week factor levels
    uif_dev: DevMoments          # corrector moments on daily-mean devs
    # daily flexible usage T_UF
    flex_ring: jnp.ndarray       # (n, 7)
    flex_wmean: jnp.ndarray      # (n,)
    flex_dow: jnp.ndarray        # (n, 7) day-of-week factor levels
    flex_dev: DevMoments
    # daily total reservations T_R
    res_ring: jnp.ndarray        # (n, 7)
    res_wmean: jnp.ndarray       # (n,)
    res_dow: jnp.ndarray         # (n, 7)
    res_dev: DevMoments
    # reservations-to-usage ratio R(h) = a + b log u
    ratio: EWMoments
    # exact trailing-error rings (scalar per day)
    theta_err_ring: jnp.ndarray  # (n, <=90) daily T_R relative errors
    gamma_err_ring: jnp.ndarray  # (n, <=28) daily (1-gamma) U_IF error q
    # exact usage window for the PD power refits (breakpoints are window
    # quantiles -> power_stage over this ring == rescan bitwise)
    usage_ring: jnp.ndarray      # (n, <=28, 24)


def pytree_nbytes(tree) -> int:
    """Total bytes of a pytree's array leaves (concrete or abstract)."""
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)))


def predictor_nbytes(pred: PredictorState) -> int:
    """Total bytes of the streaming carry."""
    return pytree_nbytes(pred)


def replaced_hist_nbytes(state) -> int:
    """Bytes of the seven rescan history arrays PredictorState replaces
    (``hist_*`` in a rescan SimState/FleetState)."""
    return int(sum(getattr(state, k).size * getattr(state, k).dtype.itemsize
                   for k in ("hist_uif", "hist_flex_daily", "hist_res_daily",
                             "hist_usage", "hist_res", "hist_tr_pred",
                             "hist_uif_pred")))


# ------------------------------------------------------------ init/forecast

def _dow_slots(day, k: int) -> jnp.ndarray:
    """Day-of-week slots of the trailing ``k`` days (oldest first) when
    ``day`` is today (the next day to simulate)."""
    return (day - k + jnp.arange(k)) % WEEK


def _dow_ring(daily_hist: jnp.ndarray, day) -> jnp.ndarray:
    """Scatter the trailing 7 daily values into dow slots. (n, H) -> (n, 7)."""
    return jnp.zeros(daily_hist.shape[:1] + (WEEK,), f32).at[
        :, _dow_slots(day, WEEK)].set(daily_hist[:, -WEEK:])


def _dev_init_hourly(hourly_hist: jnp.ndarray) -> DevMoments:
    """Corrector moments from an hourly history window, computed
    per-cluster under vmap with the weekly level/factors recomputed
    locally — the same compile structure (and the same positional fold
    columns, ``forecast.POS8``) as ``forecast_inflexible``, so the
    handoff coefficient matches the rescan bitwise."""
    pos8 = jnp.asarray(forecast.POS8)

    def one(h):
        wm = forecast.weekly_mean_forecast(h.mean(axis=1))
        fa = forecast.hourly_factor_forecast(h)
        dev = h[-8:].mean(axis=1) - wm * fa[pos8].mean(axis=-1)
        return (jnp.sum(dev[:-1] * dev[:-1]),
                jnp.sum(dev[:-1] * dev[1:]), dev[-1])
    sxx, sxy, prev = jax.vmap(one)(hourly_hist)
    return DevMoments(sxx=sxx, sxy=sxy, prev=prev)


def _dev_init_daily(daily_hist: jnp.ndarray) -> DevMoments:
    """Corrector moments from a daily-total history window (mirrors
    ``forecast_daily_total``'s fit, per-cluster under vmap)."""
    pos8 = jnp.asarray(forecast.POS8)

    def one(d):
        wm = forecast.weekly_mean_forecast(d)
        fa = forecast.daily_factor_forecast(d)
        dev = d[-8:] - wm * fa[pos8]
        return (jnp.sum(dev[:-1] * dev[:-1]),
                jnp.sum(dev[:-1] * dev[1:]), dev[-1])
    sxx, sxy, prev = jax.vmap(one)(daily_hist)
    return DevMoments(sxx=sxx, sxy=sxy, prev=prev)


def init_predictor(hist_uif, hist_flex_daily, hist_res_daily, hist_usage,
                   hist_res, hist_tr_pred, hist_uif_pred, day, gamma
                   ) -> PredictorState:
    """Warm-start every streaming estimator from a burned-in history
    window (the arrays a rescan ``SimState`` carries; ``day`` is the next
    day to simulate). EWMA levels and corrector moments are computed by
    the SAME rescan functions/op-orders, so the handoff-day streaming
    forecast matches the rescan bitwise on the EWMA components."""
    n, H = hist_uif.shape[0], hist_uif.shape[1]
    if H < WEEK:
        raise ValueError(f"streaming init needs >= {WEEK} days of history, "
                         f"got {H}")

    # the rescan fold is positional (column j <-> absolute dow
    # (day + j) % 7 — the trailing whole-week window starts on the
    # forecast day's dow); rolling by `day` converts the levels to the
    # ABSOLUTE dow slots the streaming carry indexes by
    def abs_slots(factors):
        return jnp.roll(factors, day, axis=1)

    uif_daily = hist_uif.mean(axis=2)                       # (n, H)
    uif_wmean = jax.vmap(forecast.weekly_mean_forecast)(uif_daily)
    uif_how = abs_slots(jax.vmap(forecast.hourly_factor_forecast)(hist_uif))
    uif_dev = _dev_init_hourly(hist_uif)

    flex_wmean = jax.vmap(forecast.weekly_mean_forecast)(hist_flex_daily)
    flex_dow = abs_slots(
        jax.vmap(forecast.daily_factor_forecast)(hist_flex_daily))
    flex_dev = _dev_init_daily(hist_flex_daily)

    res_wmean = jax.vmap(forecast.weekly_mean_forecast)(hist_res_daily)
    res_dow = abs_slots(
        jax.vmap(forecast.daily_factor_forecast)(hist_res_daily))
    res_dev = _dev_init_daily(hist_res_daily)

    u28 = hist_usage[:, -USAGE_WINDOW:]
    r28 = hist_res[:, -USAGE_WINDOW:]
    x = jnp.log(jnp.clip(u28, 1e-9, None)).reshape(n, -1)
    y = (r28 / jnp.clip(u28, 1e-9, None)).reshape(n, -1)
    ratio = ew_init(x, y)

    th = hist_tr_pred[:, -THETA_WINDOW:]
    theta_err = (hist_res_daily[:, -THETA_WINDOW:] - th) \
        / jnp.clip(jnp.abs(th), 1e-9, None)
    up = hist_uif_pred[:, -GAMMA_WINDOW:]
    eps_h = (hist_uif[:, -GAMMA_WINDOW:] - up) \
        / jnp.clip(jnp.abs(up), 1e-9, None)               # (n, W, 24)
    gamma_err = jnp.quantile(eps_h, 1.0 - gamma, axis=2)  # (n, W)

    return PredictorState(
        uif_day_ring=_dow_ring(uif_daily, day),
        uif_prev=hist_uif[:, -1],
        uif_wmean=uif_wmean, uif_how=uif_how, uif_dev=uif_dev,
        flex_ring=_dow_ring(hist_flex_daily, day),
        flex_wmean=flex_wmean, flex_dow=flex_dow, flex_dev=flex_dev,
        res_ring=_dow_ring(hist_res_daily, day),
        res_wmean=res_wmean, res_dow=res_dow, res_dev=res_dev,
        ratio=ratio,
        theta_err_ring=theta_err.astype(f32),
        gamma_err_ring=gamma_err.astype(f32),
        usage_ring=u28)


def streaming_forecast(pred: PredictorState, day, gamma
                       ) -> Dict[str, jnp.ndarray]:
    """Next-day forecast dict (same keys as ``stages.forecast_stage``)
    from the streaming carry — O(1) in history length. ``day`` is the
    day being forecast; ``day``/``gamma`` may be traced."""
    dow = day % WEEK
    dow_prev = (day - 1) % WEEK

    # U_IF(h): weekly level x hour-of-week factors + prev-day correction
    base = pred.uif_wmean[:, None] * pred.uif_how[:, dow]
    prev_pred = pred.uif_wmean[:, None] * pred.uif_how[:, dow_prev]
    dev_prev = pred.uif_prev - prev_pred
    uif = jnp.clip(base + dev_coef(pred.uif_dev)[:, None] * dev_prev,
                   0.0, None)

    # T_UF(d), T_R(d): weekly level x dow factors + prev-day correction
    def daily_total(ring, wmean, dow_f, dev):
        nxt = wmean * dow_f[:, dow]
        prev = wmean * dow_f[:, dow_prev]
        return jnp.clip(nxt + dev_coef(dev) * (ring[:, dow_prev] - prev),
                        0.0, None)

    tuf = daily_total(pred.flex_ring, pred.flex_wmean, pred.flex_dow,
                      pred.flex_dev)
    tr = daily_total(pred.res_ring, pred.res_wmean, pred.res_dow,
                     pred.res_dev)

    ra, rb = ew_linfit(pred.ratio)
    eps97 = ring_quantile(pred.theta_err_ring, 0.97)
    theta = forecast.theta_requirement(tr, eps97)
    alpha = jax.vmap(forecast.alpha_inflation)(theta, uif, tuf, ra, rb)
    # (1-gamma) hourly inflexible error: trailing mean of the DAILY
    # (1-gamma) hour-quantiles (the rescan pools 28x24 hourly errors; the
    # ring compresses each day to one scalar — documented approximation)
    epsq = jnp.mean(pred.gamma_err_ring, axis=1)
    uif_q = uif * (1.0 + jnp.clip(epsq, 0.0, 1.0)[:, None])
    return {"uif": uif, "tuf": tuf, "tr": tr, "ratio_a": ra, "ratio_b": rb,
            "theta": theta, "alpha": alpha, "uif_q": uif_q}


def predictor_update(pred: PredictorState, fc: Dict[str, jnp.ndarray],
                     day, gamma, u_if, flex_daily, res_daily, usage_total,
                     reservations) -> PredictorState:
    """Absorb one observed day — O(1) in history length.

    ``fc`` is the forecast issued for this ``day`` (so prediction errors
    pair same-day like the rescan's ``hist_*_pred`` rolls); ``u_if``,
    ``usage_total``, ``reservations`` are (n, 24) actuals; ``flex_daily``
    / ``res_daily`` are (n,) daily totals."""
    dow = day % WEEK
    rho_dev = decay_from_half_life(DEV_HL_DAYS)
    rho_ratio = decay_from_half_life(RATIO_HL_DAYS)
    a_mean = forecast.ewma_alpha(WMEAN_HL_DAYS)
    a_factor = forecast.ewma_alpha(4.0)      # weekly cadence per dow slot

    # exact error rings (same-day prediction/actual pairing)
    tr_err = (res_daily - fc["tr"]) / jnp.clip(jnp.abs(fc["tr"]), 1e-9,
                                               None)
    eps_h = (u_if - fc["uif"]) / jnp.clip(jnp.abs(fc["uif"]), 1e-9, None)
    gamma_err = jnp.quantile(eps_h, 1.0 - gamma, axis=1)

    # deviations vs the PRE-update levels (the prediction actually made)
    uif_daily = u_if.mean(axis=1)
    dev_u = uif_daily - pred.uif_wmean * pred.uif_how[:, dow].mean(axis=-1)
    dev_f = flex_daily - pred.flex_wmean * pred.flex_dow[:, dow]
    dev_r = res_daily - pred.res_wmean * pred.res_dow[:, dow]

    # trailing-week rings, then the EWMA level updates on them
    uif_ring = pred.uif_day_ring.at[:, dow].set(uif_daily)
    flex_ring = pred.flex_ring.at[:, dow].set(flex_daily)
    res_ring = pred.res_ring.at[:, dow].set(res_daily)
    wk_u = uif_ring.mean(axis=1)
    wk_f = flex_ring.mean(axis=1)
    wk_r = res_ring.mean(axis=1)

    x = jnp.log(jnp.clip(usage_total, 1e-9, None))
    y = reservations / jnp.clip(usage_total, 1e-9, None)

    return pred._replace(
        uif_day_ring=uif_ring, uif_prev=u_if,
        uif_wmean=forecast.ewma_update(pred.uif_wmean, wk_u, a_mean),
        uif_how=pred.uif_how.at[:, dow].set(forecast.ewma_update(
            pred.uif_how[:, dow],
            u_if / jnp.clip(wk_u[:, None], 1e-9, None), a_factor)),
        uif_dev=dev_update(pred.uif_dev, dev_u, rho_dev),
        flex_ring=flex_ring,
        flex_wmean=forecast.ewma_update(pred.flex_wmean, wk_f, a_mean),
        flex_dow=pred.flex_dow.at[:, dow].set(forecast.ewma_update(
            pred.flex_dow[:, dow],
            flex_daily / jnp.clip(wk_f, 1e-9, None), a_factor)),
        flex_dev=dev_update(pred.flex_dev, dev_f, rho_dev),
        res_ring=res_ring,
        res_wmean=forecast.ewma_update(pred.res_wmean, wk_r, a_mean),
        res_dow=pred.res_dow.at[:, dow].set(forecast.ewma_update(
            pred.res_dow[:, dow],
            res_daily / jnp.clip(wk_r, 1e-9, None), a_factor)),
        res_dev=dev_update(pred.res_dev, dev_r, rho_dev),
        ratio=ew_update(pred.ratio, x, y, rho_ratio),
        theta_err_ring=ring_push(pred.theta_err_ring, tr_err),
        gamma_err_ring=ring_push(pred.gamma_err_ring, gamma_err),
        usage_ring=ring_push(pred.usage_ring, usage_total))


# ------------------------------------------------- hour-grain advancement

class HourAccum(NamedTuple):
    """Partial-day accumulator: the hour-grain extension of the day-grain
    ``predictor_update`` recursion. The MPC recourse loop (``core.mpc``)
    pushes one observed hour at a time; ``hour_finalize`` absorbs the
    completed day into the ``PredictorState`` carry.

    Columns are scattered in hour order and the daily totals accumulate
    by the SAME ordered adds as ``admission.hour_sum``, so chaining 24
    ``hour_update`` calls and finalizing is BITWISE identical to the
    daily batch ``predictor_update`` on the assembled arrays
    (property-tested in tests/test_mpc_properties.py)."""
    hour: jnp.ndarray            # () int32 hours absorbed so far
    u_if: jnp.ndarray            # (n, 24) realized inflexible columns
    use_flex: jnp.ndarray        # (n, 24) realized flexible columns
    usage: jnp.ndarray           # (n, 24) u_if + use_flex
    res: jnp.ndarray             # (n, 24) reservations = usage * ratio
    flex_daily: jnp.ndarray      # (n,) ordered running sum of use_flex
    res_daily: jnp.ndarray       # (n,) ordered running sum of res


def hour_accum_init(n: int) -> HourAccum:
    z24 = jnp.zeros((n, 24), f32)
    return HourAccum(hour=jnp.zeros((), jnp.int32), u_if=z24,
                     use_flex=z24, usage=z24, res=z24,
                     flex_daily=jnp.zeros((n,), f32),
                     res_daily=jnp.zeros((n,), f32))


def hour_update(acc: HourAccum, hour, u_if_h, use_flex_h, ratio_h
                ) -> HourAccum:
    """Absorb one observed hour — O(1) work per step, O(n * 24) state.
    ``hour`` may be traced (the MPC sub-scan carries it); ``u_if_h`` /
    ``use_flex_h`` / ``ratio_h`` are (n,) actuals for that hour."""
    usage_h = u_if_h + use_flex_h
    res_h = usage_h * ratio_h
    return HourAccum(
        hour=acc.hour + 1,
        u_if=acc.u_if.at[:, hour].set(u_if_h),
        use_flex=acc.use_flex.at[:, hour].set(use_flex_h),
        usage=acc.usage.at[:, hour].set(usage_h),
        res=acc.res.at[:, hour].set(res_h),
        # ordered adds in ascending-hour order == admission.hour_sum
        flex_daily=acc.flex_daily + use_flex_h,
        res_daily=acc.res_daily + res_h)


def hour_finalize(pred: PredictorState, acc: HourAccum,
                  fc: Dict[str, jnp.ndarray], day, gamma) -> PredictorState:
    """Close the day: absorb the hour-grain accumulator into the
    streaming carry. Equals the daily batch ``predictor_update`` on the
    same realized arrays (the accumulator reconstructs them exactly)."""
    return predictor_update(pred, fc, day, gamma, acc.u_if,
                            acc.flex_daily, acc.res_daily, acc.usage,
                            acc.res)
