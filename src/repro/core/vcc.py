"""Risk-aware day-ahead VCC optimization (paper §III-C, eq. 4).

Per cluster c and hour h, choose flexible-usage deviations delta(c,h) from
the hourly average tau/24, minimizing

    lambda_e * sum_{c,h} eta(c,h) * [Pow(U_nom) + pi(U_nom) * delta * tau/24]
  + lambda_p * sum_c  y_c ,                    y_c >= Pow_c(h)  for all h

subject to
  * daily conservation        sum_h delta(c,h) = 0
  * power-capping (chance)    (1+delta) tau/24 <= U_pow - (U_IF)_{1-gamma}(h)
  * machine capacity          VCC(c,h) = (U_IF + (1+delta) tau/24) R(h) <= C
  * campus contracts          sum_{c in dc} y_c <= L_cont(dc)
  * delta >= -1               (flexible usage cannot go negative)

Solver: projected gradient on delta (the objective is linear + a smooth-max
peak term), with an EXACT O(iter x n x 24) bisection projection onto
{sum_h delta = 0} ∩ [lo, ub], and dual ascent on the campus coupling — all
assembled from the generic PGD pieces in ``repro.core.solver`` (this module
keeps NO private solver machinery). The fused PGD step is the CICS
fleet-scale hotspot and has a Pallas kernel (repro.kernels.vcc_pgd).

Clusters whose bounds make shaping infeasible (too full / tau ~ 0) are
excluded and get VCC = machine capacity (paper: ~10% of clusters per day).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import solver
from repro.core.admission import hour_sum
from repro.kernels.vcc_pgd import ref as _pgd_ref

f32 = jnp.float32


@dataclass(frozen=True)
class VCCProblem:
    """Stacked fleetwide problem. n = clusters, H = 24.

    The optional ensemble axes carry K day-ahead forecast *realizations*
    (member 0 is the point forecast by convention; ``repro.core.risk``
    samples them from the empirical relative-error history) and turn the
    optimizer's objective into a soft CVaR over members — ``risk_beta`` is
    the averaged worst-tail fraction (1.0 = risk-neutral mean = the
    eq. 4 point-forecast path).
    """
    eta: jnp.ndarray          # (n, H) carbon intensity forecast kg/kWh
    u_if: jnp.ndarray         # (n, H) predicted inflexible CPU
    u_if_q: jnp.ndarray       # (n, H) (1-gamma) quantile of inflexible CPU
    tau: jnp.ndarray          # (n,)  risk-aware daily flexible CPU (alpha*T)
    pow_nom: jnp.ndarray      # (n, H) power at nominal usage (kW)
    pi: jnp.ndarray           # (n, H) power slope at nominal usage (kW/CPU)
    u_pow_cap: jnp.ndarray    # (n,)  power-capping CPU threshold
    capacity: jnp.ndarray     # (n,)  machine capacity (CPU)
    ratio: jnp.ndarray        # (n, H) reservations-to-usage ratio R(h)
    campus: jnp.ndarray       # (n,) int campus id
    campus_limit: jnp.ndarray  # (n_dc,) power limits (kW)
    lambda_e: float = 0.05    # $ / kg CO2e
    lambda_p: float = 0.1     # $ / kW / day
    # forecast-ensemble axes (None = point-forecast problem, eq. 4)
    eta_ens: Optional[jnp.ndarray] = None      # (K, n, H) intensity members
    pow_nom_ens: Optional[jnp.ndarray] = None  # (K, n, H) nominal power
    risk_beta: float = 1.0    # CVaR tail fraction (1.0 = risk-neutral)
    # paper §III-C "other constraints": bound the allowed intraday drop in
    # flexible usage (1.0 = flexible may drop to zero)
    drop_limit: float = 0.8


# Pytree registration: every field except the static drop_limit is data, so
# stacked problems can cross vmap/scan boundaries (sim engine, sweeps).
# lambda_e / lambda_p / risk_beta are data leaves — scenario sweeps batch
# them; the None ensemble fields flatten to empty subtrees until attached.
jax.tree_util.register_dataclass(
    VCCProblem,
    data_fields=["eta", "u_if", "u_if_q", "tau", "pow_nom", "pi",
                 "u_pow_cap", "capacity", "ratio", "campus", "campus_limit",
                 "lambda_e", "lambda_p", "eta_ens", "pow_nom_ens",
                 "risk_beta"],
    meta_fields=["drop_limit"])


@dataclass
class VCCSolution:
    delta: jnp.ndarray        # (n, H)
    y: jnp.ndarray            # (n,) peak power bound
    vcc: jnp.ndarray          # (n, H) hourly reservation capacity
    shaped: jnp.ndarray       # (n,) bool: cluster actively shaped
    mu: jnp.ndarray           # (n_dc,) campus duals
    objective: jnp.ndarray    # scalar


jax.tree_util.register_dataclass(
    VCCSolution,
    data_fields=["delta", "y", "vcc", "shaped", "mu", "objective"],
    meta_fields=[])


def delta_bounds(p: VCCProblem):
    """Per (c,h) bounds on delta + feasibility mask."""
    tau24 = jnp.clip(p.tau[:, None] / 24.0, 1e-9, None)
    ub_pow = (p.u_pow_cap[:, None] - p.u_if_q) / tau24 - 1.0
    ub_cap = (p.capacity[:, None] / p.ratio - p.u_if) / tau24 - 1.0
    ub = jnp.minimum(ub_pow, ub_cap)
    lo = jnp.full_like(ub, -p.drop_limit)
    ub = jnp.clip(ub, -p.drop_limit, 24.0)
    # feasible to conserve the day iff sum_h ub >= 0 and tau > 0
    feasible = (ub.sum(axis=1) >= 0.0) & (p.tau > 1e-6) \
        & jnp.all(ub > -p.drop_limit + 1e-9, axis=1)
    return lo, ub, feasible


# the core-layer projection entry point (re-exported for the tests and
# legacy import sites; repro.core.solver owns the machinery)
project_conservation = solver.project_conservation


def cluster_power(p: VCCProblem, delta):
    """Hourly power under delta (local linearization around nominal)."""
    return p.pow_nom + p.pi * delta * p.tau[:, None] / 24.0


def objective(p: VCCProblem, delta, mu, *, risk: bool = True):
    """Day cost of ``delta``. Point-forecast problems get eq. 4 exactly;
    problems carrying ensemble axes get the soft-CVaR ensemble objective
    (``risk.soft_cvar_objective``) unless ``risk=False`` forces the
    nominal (member-0/point-forecast) evaluation — which is what
    ``solve_vcc`` records in ``VCCSolution.objective`` so the field stays
    comparable (and bitwise-stable) across risk settings."""
    if risk and p.eta_ens is not None:
        from repro.core import risk as _risk
        return _risk.soft_cvar_objective(p, delta, mu)
    pow_h = cluster_power(p, delta)
    y = pow_h.max(axis=1)
    carbon = p.lambda_e * jnp.sum(p.eta * pow_h)
    peak_price = p.lambda_p + mu[p.campus]
    return carbon + jnp.sum(peak_price * y)


def cluster_objective(p: VCCProblem, delta):
    """Per-cluster nominal (eq. 4, mu-free primal) day cost of ``delta``:
    lambda_e * sum_h eta * pow + lambda_p * max_h pow, as an (n,) vector.
    Ordered reductions only (``hour_sum``; max is order-exact), so the
    telemetry channels built from it stay bitwise batch-invariant."""
    pow_h = cluster_power(p, delta)
    return p.lambda_e * hour_sum(p.eta * pow_h) \
        + p.lambda_p * pow_h.max(axis=1)


def solution_diagnostics(p: VCCProblem, delta, mu, *,
                         temp_frac: float = 0.02, proj_iters: int = 50):
    """Post-solve convergence residuals of ``(delta, mu)`` — the in-graph
    solver telemetry channels. Elementwise + ordered reductions only
    (bitwise batch-invariant; the cluster axis is NOT reduced — host-side
    consumers reduce it).

    Returns a dict of arrays:
      * ``conservation_resid`` (n,) — |sum_h delta| per cluster, the
        residual the bisection projection drives to ~0.
      * ``proj_nu_tol`` (n,) — certified tolerance of the conservation
        projection's nu bisection at the solution: the initial bracket
        width (``kernels.vcc_pgd.ref.project_row``'s [a, b]) halved
        ``proj_iters`` times.
      * ``dual_resid`` (n_dc,) — relative campus-contract overshoot
        max(0, (sum_c y - L) / L) at the final point (0 = the campus
        dual ascent converged feasibly).
      * ``cvar_tail_mass`` (n,) — max soft-CVaR member weight per cluster
        at the final delta (K > 1 problems; 1/K = risk-neutral-uniform,
        -> 1 = the tilt concentrates on one worst member). Point-forecast
        problems report the degenerate 1.0.
    """
    conservation = jnp.abs(hour_sum(delta))
    lo, ub, feasible = delta_bounds(p)
    lo = jnp.where(feasible[:, None], lo, 0.0)
    ub = jnp.where(feasible[:, None], ub, 0.0)
    width0 = jnp.clip((delta.max(axis=1) - lo.min(axis=1))
                      - (delta.min(axis=1) - ub.max(axis=1)), 0.0, None)
    proj_tol = width0 * (2.0 ** -proj_iters)
    y = cluster_power(p, delta).max(axis=1)
    campus_pow = jax.ops.segment_sum(y, p.campus,
                                     num_segments=p.campus_limit.shape[0])
    dual_resid = jnp.clip((campus_pow - p.campus_limit)
                          / jnp.clip(p.campus_limit, 1e-9, None), 0.0, None)
    if p.eta_ens is not None and p.eta_ens.shape[0] > 1:
        tau24 = jnp.clip(p.tau[:, None] / 24.0, 1e-9, None)
        price = (p.lambda_p + mu[p.campus])[:, None]
        temp = solver.peak_temperature(p.pow_nom, temp_frac)
        cost, _, _ = _pgd_ref.member_costs(
            delta, p.eta_ens, p.pi, p.pow_nom_ens, tau24, price, temp,
            p.lambda_e)
        tail = _pgd_ref.cvar_member_weights(
            cost, _pgd_ref.cvar_sharpness(p.risk_beta)).max(axis=0)
    else:
        tail = jnp.ones_like(p.tau)
    return {"conservation_resid": conservation, "proj_nu_tol": proj_tol,
            "dual_resid": dual_resid, "cvar_tail_mass": tail}


def solve_vcc(p: VCCProblem, *, inner_iters: int = 80, outer_iters: int = 20,
              lr: float = 0.5, temp_frac: float = 0.02, rho: float = 0.2,
              use_pallas: Optional[bool] = None,
              interpret: bool = False, telemetry: bool = False):
    """Solve the fleetwide VCC problem (eq. 4).

    Assembly over ``repro.core.solver``: scaled-lr PGD epochs
    (``solver.pgd_epochs`` — the fleet-wide kernel dispatch convention:
    ``use_pallas=None`` auto-selects the Pallas kernel on TPU and the jnp
    oracle elsewhere; ``interpret=True`` exercises the kernel through the
    Pallas interpreter on CPU) inside ``solver.dual_ascent`` on the
    campus power couplings.

    Ensemble problems (K members attached via ``risk.attach_ensemble``)
    descend the soft-CVaR member tilt in the same epoch; a K=1 ensemble is
    statically collapsed to the point-forecast problem, so the degenerate
    risk path traces the EXACT legacy graph (bitwise contract, tested).
    ``VCCSolution.objective`` is always the nominal eq. 4 cost of the
    chosen delta (comparable across risk settings; the risk value is
    ``risk.cvar_objective``).

    ``telemetry=True`` returns ``(solution, diag)`` where ``diag`` adds
    the solver convergence channels: per-outer-round per-cluster nominal
    objective (``obj_cluster_traj`` (outer_iters, n)) and max step
    (``step_max_traj`` (outer_iters, n)) from the dual-ascent scan, plus
    ``solution_diagnostics`` at the final point. The default
    ``telemetry=False`` path traces the EXACT legacy graph (byte-identical
    compiled HLO — the repo's collapse contract, tested).
    """
    if p.eta_ens is not None and p.eta_ens.shape[0] == 1:
        p = dataclasses.replace(p, eta_ens=None, pow_nom_ens=None)
    n, H = p.eta.shape
    lo, ub, feasible = delta_bounds(p)
    # neutralize infeasible clusters: bounds collapse to {0}
    lo = jnp.where(feasible[:, None], lo, 0.0)
    ub = jnp.where(feasible[:, None], ub, 0.0)
    temp = solver.peak_temperature(p.pow_nom, temp_frac)
    n_dc = p.campus_limit.shape[0]
    lr_eff = solver.scaled_lr(lr, p.pi, p.tau, p.eta, p.lambda_e,
                              p.lambda_p)

    def inner(delta, mu):
        return solver.pgd_epochs(p, delta, mu, lo, ub, lr_eff, temp,
                                 inner_iters, use_pallas=use_pallas,
                                 interpret=interpret)

    def dual_update(delta, mu):
        y = cluster_power(p, delta).max(axis=1)
        return solver.campus_dual_update(mu, y, p.campus, p.campus_limit,
                                         rho)

    if telemetry:
        def diag_fn(d_prev, d_new, _mu):
            return {"obj_cluster": cluster_objective(p, d_new),
                    "step_max": jnp.abs(d_new - d_prev).max(axis=1)}

        delta, mu, traj = solver.dual_ascent(inner, dual_update,
                                             jnp.zeros((n, H), f32),
                                             jnp.zeros((n_dc,), f32),
                                             outer_iters, diag_fn=diag_fn)
    else:
        delta, mu = solver.dual_ascent(inner, dual_update,
                                       jnp.zeros((n, H), f32),
                                       jnp.zeros((n_dc,), f32), outer_iters)
    pow_h = cluster_power(p, delta)
    y = pow_h.max(axis=1)
    vcc_shaped = (p.u_if + (1.0 + delta) * p.tau[:, None] / 24.0) * p.ratio
    vcc = jnp.where(feasible[:, None],
                    jnp.minimum(vcc_shaped, p.capacity[:, None]),
                    p.capacity[:, None])
    sol = VCCSolution(delta=delta, y=y, vcc=vcc, shaped=feasible, mu=mu,
                      objective=objective(p, delta, mu, risk=False))
    if not telemetry:
        return sol
    diag = {"obj_cluster_traj": traj["obj_cluster"],
            "step_max_traj": traj["step_max"],
            **solution_diagnostics(p, delta, mu, temp_frac=temp_frac)}
    return sol, diag


def suffix_bounds(p: VCCProblem, delta_committed, hour):
    """Bounds of the masked suffix polytope at intra-day ``hour`` (0-23,
    may be traced): elapsed hours (h < hour) are pinned at the REALIZED
    deviations ``delta_committed``, remaining hours keep the day-ahead
    box. The exact bisection projection onto {sum_h delta = 0} ∩ [lo, ub]
    then enforces the TIGHTENED suffix conservation
    ``sum_{h >= hour} delta = -sum_{h < hour} delta_committed`` for free
    — no new solver math.

    Feasibility needs both box sums to bracket zero (the day-ahead check
    only needs ``sum ub >= 0`` because its lo is the constant
    -drop_limit); clusters whose realized prefix cannot be conserved any
    more are pinned to ``delta_committed`` everywhere — the projection
    returns a lo==ub row exactly, so infeasible clusters simply keep
    their current plan. Returns (lo, ub, feasible)."""
    mask = jnp.arange(24) >= hour                       # True = remaining
    lo, ub, feasible = delta_bounds(p)
    lo = jnp.where(mask[None, :], lo, delta_committed)
    ub = jnp.where(mask[None, :], ub, delta_committed)
    feasible = feasible & (hour_sum(lo) <= 1e-6) \
        & (hour_sum(ub) >= -1e-6)
    lo = jnp.where(feasible[:, None], lo, delta_committed)
    ub = jnp.where(feasible[:, None], ub, delta_committed)
    return lo, ub, feasible


def solve_vcc_suffix(p: VCCProblem, delta0, mu0, hour, *,
                     inner_iters: int = 8, outer_iters: int = 2,
                     lr: float = 0.5, temp_frac: float = 0.02,
                     rho: float = 0.2, use_pallas: Optional[bool] = None,
                     interpret: bool = False) -> VCCSolution:
    """Warm-started intra-day re-solve of the REMAINING hours' VCC.

    ``delta0`` (n, 24): the current plan with elapsed columns (h < hour)
    replaced by the realized deviations; ``mu0``: campus duals carried
    from the day-ahead solve (the warm start is what makes the short
    schedule converge). Machinery is exactly ``solve_vcc``'s —
    ``solver.pgd_epochs`` inside ``solver.dual_ascent`` with the
    projection acting on the masked suffix polytope (``suffix_bounds``)
    — but the default schedule is outer 2 x inner 8 = 16 PGD steps vs
    the full solve's 20 x 80 = 1600: the < 1/24-of-a-day-solve recourse
    budget the ROADMAP gate demands (benchmarks/sim_bench.py)."""
    n, H = p.eta.shape
    lo, ub, feasible = suffix_bounds(p, delta0, hour)
    temp = solver.peak_temperature(p.pow_nom, temp_frac)
    n_dc = p.campus_limit.shape[0]
    lr_eff = solver.scaled_lr(lr, p.pi, p.tau, p.eta, p.lambda_e,
                              p.lambda_p)

    def inner(delta, mu):
        return solver.pgd_epochs(p, delta, mu, lo, ub, lr_eff, temp,
                                 inner_iters, use_pallas=use_pallas,
                                 interpret=interpret)

    def dual_update(delta, mu):
        y = cluster_power(p, delta).max(axis=1)
        return solver.campus_dual_update(mu, y, p.campus, p.campus_limit,
                                         rho)

    delta, mu = solver.dual_ascent(inner, dual_update, delta0, mu0,
                                   outer_iters)
    pow_h = cluster_power(p, delta)
    y = pow_h.max(axis=1)
    vcc_shaped = (p.u_if + (1.0 + delta) * p.tau[:, None] / 24.0) * p.ratio
    vcc = jnp.where(feasible[:, None],
                    jnp.minimum(vcc_shaped, p.capacity[:, None]),
                    p.capacity[:, None])
    return VCCSolution(delta=delta, y=y, vcc=vcc, shaped=feasible, mu=mu,
                       objective=objective(p, delta, mu, risk=False))


def solve_vcc_batched(p: VCCProblem, **kw) -> VCCSolution:
    """vmap solve_vcc over a leading (scenario x seed) axis of a stacked
    VCCProblem (requires the pytree registration above)."""
    return jax.vmap(lambda q: solve_vcc(q, **kw))(p)


def synthetic_problem(n: int = 12, seed: int = 7, n_campuses: int = 2
                      ) -> VCCProblem:
    """The canonical synthetic fleetwide problem shared by the parity
    tests (tests/test_stages_parity.py, tests/test_risk.py) and the
    solve-cost benchmark probe (benchmarks/sim_bench.py): a diurnal
    intensity curve + noisy inflexible load with uncontended campus
    limits and drop_limit=1.0. ONE recipe so the benchmarked problem can
    never drift from the tested one."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    H = 24
    eta = jnp.abs(0.3 + 0.25 * jnp.sin(jnp.linspace(0, 2 * jnp.pi, H))[None]
                  + 0.05 * jax.random.normal(ks[0], (n, H)))
    u_if = 0.4 + 0.05 * jax.random.normal(ks[1], (n, H))
    tau = 2.0 + 3.0 * jax.random.uniform(ks[2], (n,))
    pow_nom = 500.0 + 20.0 * jax.random.normal(ks[3], (n, H))
    import numpy as np
    return VCCProblem(
        eta=eta, u_if=u_if, u_if_q=u_if * 1.1, tau=tau,
        pow_nom=pow_nom, pi=jnp.full((n, H), 300.0),
        u_pow_cap=jnp.full((n,), 0.95), capacity=jnp.full((n,), 1.3),
        ratio=jnp.full((n, H), 1.3),
        campus=jnp.asarray(np.arange(n) % n_campuses, jnp.int32),
        campus_limit=jnp.full((n_campuses,), 1e9),
        lambda_e=0.1, lambda_p=0.05, drop_limit=1.0)


def synthetic_zonal_problem(n: int = 12, seed: int = 3,
                            n_campuses: int = 2) -> VCCProblem:
    """``synthetic_problem`` with a strong spatial carbon gradient
    (alternating dirty/clean clusters) and tightened machine capacity, so
    temporal shaping saturates in the dirty clusters and exporting budget
    is what a spatial/joint optimizer can exploit. The ONE zonal recipe
    shared by the joint tests (tests/test_joint.py) and the
    joint-vs-sequential benchmark probe (benchmarks/sim_bench.py) — same
    convention as ``synthetic_problem``: the benchmarked problem can
    never drift from the tested one."""
    p = synthetic_problem(n, seed=seed, n_campuses=n_campuses)
    scale = jnp.where(jnp.arange(n) % 2 == 0, 2.2, 0.5)[:, None]
    return dataclasses.replace(p, eta=p.eta * scale,
                               capacity=p.capacity * 0.85)


# ------------------------------------------------- exact greedy reference

def greedy_linear_reference(eta_pi, lo, ub):
    """Exact minimizer of sum_h c_h * delta_h with sum delta = 0, box
    bounds, for ONE cluster (numpy-style; the independent oracle the tests
    hold PGD and ``solver.minimize_linear`` against).

    Classic exchange argument: push delta to ub at the cheapest hours and lo
    at the most expensive, with one marginal hour balancing the budget.
    """
    import numpy as np
    c = np.asarray(eta_pi, dtype=np.float64)
    lo = np.asarray(lo, np.float64).copy()
    ub = np.asarray(ub, np.float64).copy()
    order = np.argsort(c)
    delta = lo.copy()                 # start everything at lower bound
    budget = -delta.sum()             # must add this much
    for h in order:                   # fill cheapest hours first
        room = ub[h] - delta[h]
        add = min(room, budget)
        delta[h] += add
        budget -= add
        if budget <= 1e-12:
            break
    return delta
