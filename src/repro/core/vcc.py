"""Risk-aware day-ahead VCC optimization (paper §III-C, eq. 4).

Per cluster c and hour h, choose flexible-usage deviations delta(c,h) from
the hourly average tau/24, minimizing

    lambda_e * sum_{c,h} eta(c,h) * [Pow(U_nom) + pi(U_nom) * delta * tau/24]
  + lambda_p * sum_c  y_c ,                    y_c >= Pow_c(h)  for all h

subject to
  * daily conservation        sum_h delta(c,h) = 0
  * power-capping (chance)    (1+delta) tau/24 <= U_pow - (U_IF)_{1-gamma}(h)
  * machine capacity          VCC(c,h) = (U_IF + (1+delta) tau/24) R(h) <= C
  * campus contracts          sum_{c in dc} y_c <= L_cont(dc)
  * delta >= -1               (flexible usage cannot go negative)

Solver: projected gradient on delta (the objective is linear + a smooth-max
peak term), with an EXACT O(iter x n x 24) bisection projection onto
{sum_h delta = 0} ∩ [lo, ub], and dual ascent on the campus coupling. The
fused PGD step is the CICS fleet-scale hotspot and has a Pallas kernel
(repro.kernels.vcc_pgd); this module is the jnp reference path.

Clusters whose bounds make shaping infeasible (too full / tau ~ 0) are
excluded and get VCC = machine capacity (paper: ~10% of clusters per day).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.vcc_pgd import ref as _pgd_ref

f32 = jnp.float32


@dataclass(frozen=True)
class VCCProblem:
    """Stacked fleetwide problem. n = clusters, H = 24."""
    eta: jnp.ndarray          # (n, H) carbon intensity forecast kg/kWh
    u_if: jnp.ndarray         # (n, H) predicted inflexible CPU
    u_if_q: jnp.ndarray       # (n, H) (1-gamma) quantile of inflexible CPU
    tau: jnp.ndarray          # (n,)  risk-aware daily flexible CPU (alpha*T)
    pow_nom: jnp.ndarray      # (n, H) power at nominal usage (kW)
    pi: jnp.ndarray           # (n, H) power slope at nominal usage (kW/CPU)
    u_pow_cap: jnp.ndarray    # (n,)  power-capping CPU threshold
    capacity: jnp.ndarray     # (n,)  machine capacity (CPU)
    ratio: jnp.ndarray        # (n, H) reservations-to-usage ratio R(h)
    campus: jnp.ndarray       # (n,) int campus id
    campus_limit: jnp.ndarray  # (n_dc,) power limits (kW)
    lambda_e: float = 0.05    # $ / kg CO2e
    lambda_p: float = 0.1     # $ / kW / day
    # paper §III-C "other constraints": bound the allowed intraday drop in
    # flexible usage (1.0 = flexible may drop to zero)
    drop_limit: float = 0.8


# Pytree registration: every field except the static drop_limit is data, so
# stacked problems can cross vmap/scan boundaries (sim engine, sweeps).
# lambda_e / lambda_p are data leaves — scenario sweeps batch them.
jax.tree_util.register_dataclass(
    VCCProblem,
    data_fields=["eta", "u_if", "u_if_q", "tau", "pow_nom", "pi",
                 "u_pow_cap", "capacity", "ratio", "campus", "campus_limit",
                 "lambda_e", "lambda_p"],
    meta_fields=["drop_limit"])


@dataclass
class VCCSolution:
    delta: jnp.ndarray        # (n, H)
    y: jnp.ndarray            # (n,) peak power bound
    vcc: jnp.ndarray          # (n, H) hourly reservation capacity
    shaped: jnp.ndarray       # (n,) bool: cluster actively shaped
    mu: jnp.ndarray           # (n_dc,) campus duals
    objective: jnp.ndarray    # scalar


jax.tree_util.register_dataclass(
    VCCSolution,
    data_fields=["delta", "y", "vcc", "shaped", "mu", "objective"],
    meta_fields=[])


def delta_bounds(p: VCCProblem):
    """Per (c,h) bounds on delta + feasibility mask."""
    tau24 = jnp.clip(p.tau[:, None] / 24.0, 1e-9, None)
    ub_pow = (p.u_pow_cap[:, None] - p.u_if_q) / tau24 - 1.0
    ub_cap = (p.capacity[:, None] / p.ratio - p.u_if) / tau24 - 1.0
    ub = jnp.minimum(ub_pow, ub_cap)
    lo = jnp.full_like(ub, -p.drop_limit)
    ub = jnp.clip(ub, -p.drop_limit, 24.0)
    # feasible to conserve the day iff sum_h ub >= 0 and tau > 0
    feasible = (ub.sum(axis=1) >= 0.0) & (p.tau > 1e-6) \
        & jnp.all(ub > -p.drop_limit + 1e-9, axis=1)
    return lo, ub, feasible


def project_conservation(z, lo, ub, iters: int = 50):
    """Euclidean projection of each row onto {sum=0} ∩ [lo, ub] via
    bisection on the shift nu: sum(clip(z - nu, lo, ub)) = 0. Single
    implementation lives in the kernel package's jnp oracle."""
    return _pgd_ref.project_row(z, lo, ub, iters)


def cluster_power(p: VCCProblem, delta):
    """Hourly power under delta (local linearization around nominal)."""
    return p.pow_nom + p.pi * delta * p.tau[:, None] / 24.0


def smooth_peak(pow_h, temp):
    """Differentiable softmax-peak and its weights. pow_h: (n, H)."""
    w = jax.nn.softmax(pow_h / temp, axis=1)
    return jnp.sum(w * pow_h, axis=1), w


def objective(p: VCCProblem, delta, mu):
    pow_h = cluster_power(p, delta)
    y = pow_h.max(axis=1)
    carbon = p.lambda_e * jnp.sum(p.eta * pow_h)
    peak_price = p.lambda_p + mu[p.campus]
    return carbon + jnp.sum(peak_price * y)


def pgd_step(p: VCCProblem, delta, mu, lo, ub, lr, temp):
    """One projected-gradient step (the Pallas-kernelized hotspot).
    Thin adapter over the kernel package's shared step — the same math the
    Pallas kernel fuses in VMEM (no second jnp copy of the inner body)."""
    tau24 = p.tau[:, None] / 24.0
    peak_price = (p.lambda_p + mu[p.campus])[:, None]
    return _pgd_ref.pgd_step_arrays(delta, p.eta, p.pi, p.pow_nom, tau24,
                                    peak_price, lo, ub, lr, temp,
                                    p.lambda_e)


def solve_vcc(p: VCCProblem, *, inner_iters: int = 80, outer_iters: int = 20,
              lr: float = 0.5, temp_frac: float = 0.02, rho: float = 0.2,
              use_pallas: Optional[bool] = None,
              interpret: bool = False) -> VCCSolution:
    """Solve the fleetwide VCC problem (eq. 4).

    The inner PGD epoch dispatches through ``kernels.vcc_pgd.ops.pgd_epoch``
    with the fleet-wide kernel convention: ``use_pallas=None`` auto-selects
    the Pallas kernel on TPU and the jnp oracle elsewhere; ``interpret=True``
    exercises the kernel through the Pallas interpreter on CPU (tests).
    """
    n, H = p.eta.shape
    lo, ub, feasible = delta_bounds(p)
    # neutralize infeasible clusters: bounds collapse to {0}
    lo = jnp.where(feasible[:, None], lo, 0.0)
    ub = jnp.where(feasible[:, None], ub, 0.0)
    temp = temp_frac * jnp.clip(p.pow_nom.mean(), 1e-6, None)
    n_dc = p.campus_limit.shape[0]
    # gradient scale varies per cluster: normalize lr by pi*tau/24
    g_scale = jnp.clip((p.pi * p.tau[:, None] / 24.0).max(axis=1,
                                                          keepdims=True),
                       1e-9, None)
    lr_eff = lr / (g_scale * jnp.clip(
        p.lambda_e * p.eta.max(axis=1, keepdims=True) + p.lambda_p, 1e-9,
        None))

    from repro.kernels.vcc_pgd import ops as _k

    def inner(delta, mu):
        return _k.pgd_epoch(p, delta, mu, lo, ub, lr_eff, temp, inner_iters,
                            use_pallas=use_pallas, interpret=interpret)

    def outer(carry, _):
        delta, mu = carry
        delta = inner(delta, mu)
        pow_h = cluster_power(p, delta)
        y = pow_h.max(axis=1)
        campus_pow = jax.ops.segment_sum(y, p.campus, num_segments=n_dc)
        mu = jnp.clip(mu + rho * (campus_pow - p.campus_limit)
                      / jnp.clip(p.campus_limit, 1e-9, None), 0.0, None)
        return (delta, mu), None

    delta0 = jnp.zeros((n, H), f32)
    mu0 = jnp.zeros((n_dc,), f32)
    (delta, mu), _ = jax.lax.scan(outer, (delta0, mu0), None,
                                  length=outer_iters)
    pow_h = cluster_power(p, delta)
    y = pow_h.max(axis=1)
    vcc_shaped = (p.u_if + (1.0 + delta) * p.tau[:, None] / 24.0) * p.ratio
    vcc = jnp.where(feasible[:, None],
                    jnp.minimum(vcc_shaped, p.capacity[:, None]),
                    p.capacity[:, None])
    return VCCSolution(delta=delta, y=y, vcc=vcc, shaped=feasible, mu=mu,
                       objective=objective(p, delta, mu))


def solve_vcc_batched(p: VCCProblem, **kw) -> VCCSolution:
    """vmap solve_vcc over a leading (scenario x seed) axis of a stacked
    VCCProblem (requires the pytree registration above)."""
    return jax.vmap(lambda q: solve_vcc(q, **kw))(p)


# ------------------------------------------------- exact greedy reference

def greedy_linear_reference(eta_pi, lo, ub, iters_unused=None):
    """Exact minimizer of sum_h c_h * delta_h with sum delta = 0, box
    bounds, for ONE cluster (numpy-style; used to validate PGD in tests).

    Classic exchange argument: push delta to ub at the cheapest hours and lo
    at the most expensive, with one marginal hour balancing the budget.
    """
    import numpy as np
    c = np.asarray(eta_pi, dtype=np.float64)
    lo = np.asarray(lo, np.float64).copy()
    ub = np.asarray(ub, np.float64).copy()
    order = np.argsort(c)
    delta = lo.copy()                 # start everything at lower bound
    budget = -delta.sum()             # must add this much
    for h in order:                   # fill cheapest hours first
        room = ub[h] - delta[h]
        add = min(room, budget)
        delta[h] += add
        budget -= add
        if budget <= 1e-12:
            break
    return delta
