from repro.data.pipeline import DataConfig, DataLoader, batch_at

__all__ = ["DataConfig", "DataLoader", "batch_at"]
