"""Deterministic, shardable synthetic LM data pipeline.

Design goals of a production pipeline kept intact at miniature scale:
  * deterministic per (seed, step) — restart-safe batch replay (fault
    tolerance: a restarted trainer regenerates the exact batch stream);
  * host-shardable — each data-parallel host materializes only its slice;
  * prefetchable — an iterator with a bounded lookahead buffer.

The token source is a mixture of (i) a repeating Zipf-distributed unigram
stream and (ii) short arithmetic "documents" (so a ~100M model visibly
learns structure within a few hundred steps in examples/).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _batch_tokens(cfg: DataConfig, step: int, lo: int, hi: int) -> np.ndarray:
    """Rows [lo, hi) of the global batch for `step`. Deterministic."""
    rows = []
    for r in range(lo, hi):
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2**31
                                    ^ (r * 2_654_435_761 % 2**31))
        # zipf unigrams, clipped into vocab
        toks = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1)
        toks = np.clip(toks, 1, cfg.vocab_size - 1)
        # splice in arithmetic spans: "a b a+b" patterns over small ids
        n_spans = cfg.seq_len // 64
        for _ in range(n_spans):
            p = rng.randint(0, cfg.seq_len - 3)
            a, b = rng.randint(2, 50, size=2)
            toks[p:p + 3] = [a, b, (a + b) % cfg.vocab_size]
        rows.append(toks)
    return np.stack(rows).astype(np.int32)


class DataLoader:
    """Iterator of {'tokens': (local_batch, seq+1)} with prefetch."""

    def __init__(self, cfg: DataConfig, *, host_index: int = 0,
                 host_count: int = 1, start_step: int = 0,
                 prefetch: int = 2, extra_specs: Optional[Dict] = None):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.local = cfg.global_batch // host_count
        self.lo = host_index * self.local
        self.step = start_step
        self.extra_specs = extra_specs or {}
        self._q: Queue = Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        batch = {"tokens": _batch_tokens(self.cfg, step, self.lo,
                                         self.lo + self.local)}
        for name, (shape, dtype) in self.extra_specs.items():
            rng = np.random.RandomState(step % 2**31)
            batch[name] = rng.randn(self.local, *shape).astype(dtype)
        return batch

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.5)
                step += 1
            except Exception:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Whole global batch for a step (tests / single-host)."""
    return {"tokens": _batch_tokens(cfg, step, 0, cfg.global_batch)}
