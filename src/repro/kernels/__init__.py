"""Pallas TPU kernels (+ jnp oracles) for the framework's compute hot-spots.

- ``flash_attention``: block-tiled online-softmax attention (workload layer).
- ``linear_scan``: chunked gated linear attention (RWKV6 / Mamba2 mixers).
- ``vcc_pgd``: fused projected-gradient step of the paper's fleetwide VCC
  optimizer (the CICS day-ahead planning hotspot, §III-C of the paper).

Each kernel package ships ``kernel.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), ``ops.py`` (jit'd dispatching wrapper) and ``ref.py`` (pure-jnp
oracle). Kernels are validated on CPU via ``interpret=True``.
"""
