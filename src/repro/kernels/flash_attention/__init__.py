from repro.kernels.flash_attention.ops import attention  # noqa: F401
