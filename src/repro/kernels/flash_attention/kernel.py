"""Pallas TPU flash attention: block-tiled online softmax.

Tiling: grid = (B*N, nq, nk); q blocks (qb, H) and k/v blocks (kb, H) live in
VMEM; the (m, l, acc) online-softmax state lives in f32 VMEM scratch carried
across the sequential nk grid dimension. GQA is native: the kv index map
folds the query head onto its kv head (no repeat_kv materialization).
Causal/window masking skips fully-masked kv blocks via pl.when (predicated
on TPU, so skipped blocks cost no MXU work).

Static restrictions (the XLA path in ref.py covers the rest): q_offset must
be a static int, length None, Sq == Sk or q_offset-aligned decode prefixes.
Validated on CPU with interpret=True against ref.attention_reference.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QB = 128
DEFAULT_KB = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, softcap, q_offset, qb, kb, nk,
                  kv_len):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = q_offset + i * qb            # absolute pos of first q row
    q_last = q_first + qb - 1
    k_first = j * kb
    relevant = True
    if causal:
        relevant = k_first <= q_last
    if window is not None:
        # any (qpos, kpos) pair in the block can satisfy qpos - kpos < window
        relevant = jnp.logical_and(relevant,
                                   (k_first + kb - 1) > q_first - window)

    @pl.when(relevant)
    def _block():
        q = q_ref[0].astype(jnp.float32)               # (qb, H)
        k = k_ref[0].astype(jnp.float32)               # (kb, H)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_offset: int = 0, length=None,
                    scale: Optional[float] = None,
                    qb: int = DEFAULT_QB, kb: int = DEFAULT_KB,
                    interpret: bool = False):
    """q: (B, Sq, N, H); k, v: (B, Sk, K, H); N % K == 0. Returns like q."""
    assert length is None, "length masking: use the XLA path"
    assert isinstance(q_offset, int), "traced q_offset: use the XLA path"
    B, Sq, N, H = q.shape
    _, Sk, K, _ = k.shape
    G = N // K
    scale = (H ** -0.5) if scale is None else scale
    qb = min(qb, Sq)
    kb = min(kb, Sk)
    pad_q = (-Sq) % qb
    pad_k = (-Sk) % kb
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3).reshape(B * N, Sq + pad_q, H)
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3).reshape(B * K, Sk + pad_k, H)
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3).reshape(B * K, Sk + pad_k, H)
    nq = (Sq + pad_q) // qb
    nk = (Sk + pad_k) // kb

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, qb=qb, kb=kb, nk=nk, kv_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B * N, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, H), lambda b, i, j: (b, i, 0)),
            # GQA fold: query row b = batch * N + n attends kv row
            # batch * K + n // G
            pl.BlockSpec((1, kb, H),
                         lambda b, i, j, N=N, K=K, G=G:
                         ((b // N) * K + (b % N) // G, j, 0)),
            pl.BlockSpec((1, kb, H),
                         lambda b, i, j, N=N, K=K, G=G:
                         ((b // N) * K + (b % N) // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, H), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * N, Sq + pad_q, H), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),        # m
            pltpu.VMEM((qb,), jnp.float32),        # l
            pltpu.VMEM((qb, H), jnp.float32),      # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(B, N, Sq + pad_q, H).transpose(0, 2, 1, 3)
    return out[:, :Sq]
