"""Public attention op: dispatches to the Pallas TPU kernel when available,
else the bounded-memory XLA path (``ref.attention_chunked``).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention import ref as _ref


def _tpu_available() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, q_offset=0, length=None,
              scale: Optional[float] = None, q_chunk: int = 512,
              use_pallas: Optional[bool] = None, interpret: bool = False):
    """Multi-head (GQA) attention.

    q: (B, Sq, N, H); k, v: (B, Sk, K, H) with N % K == 0.
    causal/window/softcap/q_offset/length: see ``ref.attention_reference``.
    use_pallas: None = auto (TPU only). interpret: run Pallas in interpret
    mode (CPU validation).
    """
    if use_pallas is None:
        use_pallas = _tpu_available()
    if use_pallas or interpret:
        from repro.kernels.flash_attention import kernel as _kernel
        return _kernel.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, length=length, scale=scale,
            interpret=interpret)
    return _ref.attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, length=length, scale=scale, q_chunk=q_chunk)
