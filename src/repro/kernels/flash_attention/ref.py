"""Pure-jnp attention oracles.

``attention_reference`` is the exact O(S^2)-memory oracle used by kernel
tests. ``attention_chunked`` is the production XLA path: query-chunked,
bounded-memory, numerically identical rows (full-K softmax per query chunk).
Both support GQA, causal/local masking, logit soft-capping, cache-length
masking for decode, and a query position offset.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.act import constrain

NEG_INF = -1e30


def _mask(qpos, kpos, *, causal, window, length):
    """(Sq, Sk) boolean mask (True = attend). Positions are absolute."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    if length is not None:
        # length: scalar or (B,) handled by caller broadcasting; here scalar
        m &= kpos[None, :] < length
    return m


def _attend(q, k, v, scale, softcap, mask):
    """One exact attention block (native-dtype matmuls, f32 softmax).
    q: (B,Sq,N,H); k,v: (B,Sk,K,H); mask: (Sq,Sk).

    KV heads are expanded to the N query heads (repeat_kv) so the head axis
    shards cleanly on the `model` mesh axis even when K < TP (GQA). The
    Pallas TPU kernel keeps native GQA; this is the XLA path.
    """
    B, Sq, N, H = q.shape
    _, Sk, K, _ = k.shape
    G = N // K
    if Sq > 16:
        # Full-seq path: expand KV heads to N (repeat_kv) so the head axis
        # shards cleanly on `model` even when K < TP, and anchor shardings
        # (scan bodies lose them). The Pallas kernel keeps native GQA.
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
        s = jnp.einsum("bqnh,bsnh->bnqs", q, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = constrain(p, "batch", "model", None, None)
        o = jnp.einsum("bnqs,bsnh->bqnh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return constrain(o.astype(q.dtype), "batch", None, "model", None)
    # Decode path: grouped GQA einsum, no repeats, no anchors — propagation
    # follows the cache layout (heads- or head_dim-sharded); a head_dim-
    # sharded cache yields flash-decode style partial scores + psum.
    qg = q.reshape(B, Sq, K, G, H)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, N, H).astype(q.dtype)


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        q_offset=0, length=None,
                        scale: Optional[float] = None):
    """Exact attention. q: (B,Sq,N,H); k,v: (B,Sk,K,H); N % K == 0.

    q_offset: absolute position of q[0] (decode: current pos). May be traced.
    length: mask out k positions >= length (valid cache length). Scalar/traced.
    Returns (B, Sq, N, H) in q.dtype.
    """
    B, Sq, N, H = q.shape
    _, Sk, K, _ = k.shape
    scale = (H ** -0.5) if scale is None else scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    m = _mask(qpos, kpos, causal=causal, window=window, length=length)
    return _attend(q, k, v, scale, softcap, m)


def attention_chunked(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_offset=0, length=None,
                      scale: Optional[float] = None,
                      q_chunk: int = 512):
    """Query-chunked attention with bounded memory (full-K rows per chunk).

    Numerically identical to ``attention_reference`` (same row softmax).
    Memory per step: O(q_chunk * Sk) scores instead of O(Sq * Sk).
    """
    B, Sq, N, H = q.shape
    _, Sk, K, _ = k.shape
    if Sq <= q_chunk:
        return attention_reference(q, k, v, causal=causal, window=window,
                                   softcap=softcap, q_offset=q_offset,
                                   length=length, scale=scale)
    scale = (H ** -0.5) if scale is None else scale
    pad = (-Sq) % q_chunk
    nq = (Sq + pad) // q_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, q_chunk, N, H).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(Sk)

    def body(_, inp):
        qc, i = inp
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        m = _mask(qpos, kpos, causal=causal, window=window, length=length)
        o = _attend(qc, k, v, scale, softcap, m)
        return None, o

    _, out = jax.lax.scan(body, None, (qp, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, N, H)
    return out[:, :Sq]
