from repro.kernels.linear_scan.ops import gla, gla_step  # noqa: F401
