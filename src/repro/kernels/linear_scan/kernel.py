"""Pallas TPU chunked gated-linear-attention kernel.

Grid = (B*H, n_chunks); the (K, V) recurrent state lives in f32 VMEM scratch
carried across the sequential chunk dimension. Each step loads one (c, K)
q/k/decay block and (c, V) v block into VMEM, computes the intra-chunk
pairwise-decay attention (exact for arbitrarily strong decays — all
exponents <= 0), adds the inter-chunk contribution from the carried state,
and updates the state. Mirrors ref.gla_chunked; both decay modes are served
by broadcasting scalar decay to (.., K) before the call.

Validated with interpret=True against ref.gla_naive.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _gla_kernel(q_ref, k_ref, v_ref, ld_ref, u_ref, o_ref, h_ref, *,
                strict, bonus, c, nc):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    q = q_ref[0].astype(jnp.float32)           # (c, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)           # (c, V)
    ld = ld_ref[0].astype(jnp.float32)         # (c, K)
    h = h_ref[...]                             # (K, V)

    cum = jnp.cumsum(ld, axis=0)               # (c, K)
    if strict:
        cum_q = jnp.concatenate([jnp.zeros((1, cum.shape[1]), jnp.float32),
                                 cum[:-1]], axis=0)
    else:
        cum_q = cum
    # inter-chunk: query against carried state
    qs = q * jnp.exp(cum_q)
    o = jax.lax.dot_general(qs, h, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk pairwise decays: T[t,s,k] = exp(cum_q[t,k] - cum[s,k])
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    valid = (t_idx > s_idx) if strict else (t_idx >= s_idx)
    dm = cum_q[:, None, :] - cum[None, :, :]             # (c, c, K)
    dm = jnp.where(valid[:, :, None], dm, NEG_INF)
    A = jnp.sum(q[:, None, :] * k[None, :, :] * jnp.exp(dm), axis=-1)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if bonus:
        u = u_ref[0].astype(jnp.float32)                 # (1, K)
        coef = jnp.sum(q * u * k, axis=-1, keepdims=True)
        o = o + coef * v
    # state update
    cum_last = cum[-1]                                   # (K,)
    ks = k * jnp.exp(cum_last[None, :] - cum)
    h_ref[...] = jnp.exp(cum_last)[:, None] * h + jax.lax.dot_general(
        ks, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)


def gla_pallas(q, k, v, log_decay, *, bonus=None, strict: bool = False,
               chunk: int = 32, initial_state=None, interpret: bool = False):
    """q,k: (B,S,H,K); v: (B,S,H,V); log_decay: (B,S,H[,K]).
    Returns (o (B,S,H,V), final_state (B,H,K,V))."""
    assert initial_state is None, "initial_state: use the XLA path"
    B, S, H, K = q.shape
    V = v.shape[-1]
    if log_decay.ndim == 3:
        log_decay = jnp.broadcast_to(log_decay[..., None],
                                     log_decay.shape + (K,))
    c = min(chunk, S)
    pad = (-S) % c
    nc = (S + pad) // c

    def prep(x):
        cfgp = [(0, 0)] * x.ndim
        cfgp[1] = (0, pad)
        x = jnp.pad(x, cfgp)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S + pad, x.shape[-1])

    qt, kt, vt, ldt = prep(q), prep(k), prep(v), prep(log_decay)
    if bonus is None:
        u_arr = jnp.zeros((H, 1, K), jnp.float32)
    else:
        u_arr = bonus.reshape(H, 1, K).astype(jnp.float32)

    kernel = functools.partial(_gla_kernel, strict=strict,
                               bonus=bonus is not None, c=c, nc=nc)
    o = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, c, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, V), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, K), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1, K), lambda b, j, H=H: (b % H, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, V), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S + pad, V), q.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, ldt, u_arr)
    o = o.reshape(B, H, S + pad, V).transpose(0, 2, 1, 3)[:, :S]
    # final state is recomputed on the XLA path when needed (prefill); the
    # kernel is the training fast path where only outputs feed the loss.
    from repro.kernels.linear_scan import ref as _ref
    if interpret:
        _, hT = _ref.gla_chunked(q, k, v, log_decay, bonus=bonus,
                                 strict=strict, chunk=c)
        return o, hT
    return o, None
