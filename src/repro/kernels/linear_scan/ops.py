"""Public GLA op: Pallas TPU kernel when available, else chunked XLA path."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.linear_scan import ref as _ref

gla_step = _ref.gla_step  # decode step is O(1); no kernel needed


def _tpu_available() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def gla(q, k, v, log_decay, *, bonus=None, strict: bool = False,
        chunk: int = 64, initial_state=None,
        use_pallas: Optional[bool] = None, interpret: bool = False):
    """Chunked gated linear attention. See ``ref.gla_chunked`` for shapes."""
    if use_pallas is None:
        use_pallas = _tpu_available()
    if use_pallas or interpret:
        from repro.kernels.linear_scan import kernel as _kernel
        return _kernel.gla_pallas(
            q, k, v, log_decay, bonus=bonus, strict=strict, chunk=chunk,
            initial_state=initial_state, interpret=interpret)
    return _ref.gla_chunked(q, k, v, log_decay, bonus=bonus, strict=strict,
                            chunk=chunk, initial_state=initial_state)
