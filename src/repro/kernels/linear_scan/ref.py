"""Pure-jnp chunked gated-linear-attention (GLA) oracle.

One primitive covers both assigned recurrent families:

* **Mamba2 / SSD** (scalar per-head decay):  ``h_t = d_t * h_{t-1} + k_t v_t^T``,
  ``o_t = q_t @ h_t``  (inclusive, ``strict=False``).
* **RWKV6 "Finch"** (per-key-dim decay vector + bonus):
  ``h_t = diag(w_t) h_{t-1} + k_t v_t^T``,
  ``o_t = q_t @ (h_{t-1} + diag(u) k_t v_t^T)``  (``strict=True``, ``bonus=u``).

The chunked algorithm materializes intra-chunk decay products pairwise, which
is numerically exact for arbitrarily strong decays (no ``exp(-cum)`` overflow
— all pairwise exponents are ≤ 0 because decays are ≤ 1). Scalar mode uses a
(c, c) segsum per head; vector mode a (c, c, K) tensor, so callers pass a
smaller chunk (default 64 / 32).

``gla_naive`` is the O(S) sequential oracle used to validate the chunked
algorithm itself.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sharding.act import constrain

NEG_INF = -1e30


def _effective_cum(cum, strict):
    """Query-side cumulative log decay: cum[t] (inclusive) or cum[t-1] (strict)."""
    if not strict:
        return cum
    pad = [(0, 0)] * cum.ndim
    pad[1] = (1, 0)
    return jnp.pad(cum, pad)[:, :-1]


def gla_naive(q, k, v, log_decay, *, bonus=None, strict: bool = False,
              initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Sequential recurrence oracle. Shapes:
    q, k: (B, S, H, K); v: (B, S, H, V); log_decay: (B, S, H) or (B, S, H, K);
    bonus: (H, K) or None; initial_state: (B, H, K, V) or None.
    Returns (o: (B, S, H, V), final_state: (B, H, K, V)).
    """
    B, S, H, K = q.shape
    V = v.shape[-1]
    f32 = jnp.float32
    scalar = log_decay.ndim == 3
    h0 = (jnp.zeros((B, H, K, V), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h, inp):
        qt, kt, vt, ldt = inp              # (B,H,K),(B,H,K),(B,H,V),(B,H[,K])
        d = jnp.exp(ldt.astype(f32))
        d = d[..., None, None] if scalar else d[..., :, None]
        kv = kt.astype(f32)[..., :, None] * vt.astype(f32)[..., None, :]
        if strict:
            ho = h
            if bonus is not None:
                ho = ho + bonus.astype(f32)[None, :, :, None] * kv
            o = jnp.einsum("bhk,bhkv->bhv", qt.astype(f32), ho)
            h = d * h + kv
        else:
            h = d * h + kv
            o = jnp.einsum("bhk,bhkv->bhv", qt.astype(f32), h)
        return h, o

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_decay.swapaxes(0, 1))
    hT, o = jax.lax.scan(step, h0, xs)
    return o.swapaxes(0, 1).astype(q.dtype), hT


def gla_chunked(q, k, v, log_decay, *, bonus=None, strict: bool = False,
                chunk: int = 64, initial_state=None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked (parallel-within-chunk) GLA. Same contract as ``gla_naive``."""
    B, S, H, K = q.shape
    V = v.shape[-1]
    f32 = jnp.float32
    scalar = log_decay.ndim == 3
    c = min(chunk, S)
    pad = (-S) % c
    nc = (S + pad) // c

    def padseq(x, value=0.0):
        cfg = [(0, 0)] * x.ndim
        cfg[1] = (0, pad)
        return jnp.pad(x, cfg, constant_values=value)

    # pad: decay 0 (=> factor 1), k 0 (=> no state contribution)
    qp, kp, vp = padseq(q), padseq(k), padseq(v)
    ldp = padseq(log_decay)

    def chunks(x):  # (B, S', ...) -> (nc, B, c, ...)
        return x.reshape((B, nc, c) + x.shape[2:]).swapaxes(0, 1)

    h0 = (jnp.zeros((B, H, K, V), f32) if initial_state is None
          else initial_state.astype(f32))
    t_idx = jnp.arange(c)
    valid = (t_idx[:, None] > t_idx[None, :]) if strict else \
            (t_idx[:, None] >= t_idx[None, :])

    def body(h, inp):
        qc, kc, vc, ldc = inp
        qc = constrain(qc, "batch", None, "model", None).astype(f32)
        kc = constrain(kc, "batch", None, "model", None).astype(f32)
        vc = constrain(vc, "batch", None, "model", None).astype(f32)
        h = constrain(h, "batch", "model", None, None)
        cum = jnp.cumsum(ldc.astype(f32), axis=1)       # (B,c,H[,K])
        cum_q = _effective_cum(cum, strict)
        cum_last = cum[:, -1]                            # (B,H[,K])
        # --- inter-chunk: query against chunk-start state
        qs = qc * jnp.exp(cum_q if not scalar else cum_q[..., None])
        o = jnp.einsum("bthk,bhkv->bthv", qs, h)
        # --- intra-chunk
        if scalar:
            dmat = cum_q[:, :, None] - cum[:, None, :]   # (B,t,s,H)
            dmat = jnp.where(valid[None, :, :, None], dmat, NEG_INF)
            A = jnp.einsum("bthk,bshk->btsh", qc, kc) * jnp.exp(dmat)
        else:
            dmat = cum_q[:, :, None] - cum[:, None, :]   # (B,t,s,H,K)
            dmat = jnp.where(valid[None, :, :, None, None], dmat, NEG_INF)
            A = jnp.einsum("bthk,bshk,btshk->btsh", qc, kc, jnp.exp(dmat))
        o = o + jnp.einsum("btsh,bshv->bthv", A, vc)
        if bonus is not None:
            coef = jnp.einsum("bthk,hk,bthk->bth", qc, bonus.astype(f32), kc)
            o = o + coef[..., None] * vc
        # --- state update
        decay_out = jnp.exp(cum_last)                    # (B,H[,K])
        rem = cum_last[:, None] - cum                    # (B,c,H[,K])
        ks = kc * (jnp.exp(rem)[..., None] if scalar else jnp.exp(rem))
        h_new = (decay_out[..., None, None] if scalar
                 else decay_out[..., :, None]) * h
        h_new = h_new + jnp.einsum("bthk,bthv->bhkv", ks, vc)
        o = constrain(o, "batch", None, "model", None)
        return h_new, o

    hT, o = jax.lax.scan(body, h0, (chunks(qp), chunks(kp), chunks(vp),
                                    chunks(ldp)))
    o = o.swapaxes(0, 1).reshape(B, nc * c, H, V)[:, :S]
    return o.astype(q.dtype), hT


def gla_step(q, k, v, log_decay, state, *, bonus=None, strict: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. q,k: (B,H,K); v: (B,H,V); log_decay: (B,H[,K]);
    state: (B,H,K,V). Returns (o: (B,H,V), new_state)."""
    f32 = jnp.float32
    scalar = log_decay.ndim == 2
    d = jnp.exp(log_decay.astype(f32))
    d = d[..., None, None] if scalar else d[..., :, None]
    kv = k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :]
    st = state.astype(f32)
    if strict:
        ho = st
        if bonus is not None:
            ho = ho + bonus.astype(f32)[None, :, :, None] * kv
        o = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), ho)
        new = d * st + kv
    else:
        new = d * st + kv
        o = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), new)
    return o.astype(q.dtype), new
