from repro.kernels.vcc_pgd.ops import pgd_epoch  # noqa: F401
