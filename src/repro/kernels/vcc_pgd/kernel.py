"""Pallas TPU kernel: fused VCC projected-gradient epoch.

Tiling: grid = (n_clusters / TC,); each step loads a (TC, 24) cluster tile
(delta, eta, pi, pow_nom, lo, ub + per-cluster scalars) into VMEM and runs
the FULL inner optimization epoch — ``iters`` x [gradient of the linearized
carbon+peak objective → 50-step bisection projection onto the conservation
simplex slab] — without touching HBM between iterations. The day-ahead
optimizer calls this once per dual-ascent round for the whole fleet
(~O(100k) clusters x 24 h), so HBM round-trips per PGD iteration are the
hotspot being removed.

``temp`` and ``lambda_e`` ride in as broadcast (n, 1) operands rather than
compile-time constants: the day cycle derives ``temp`` from the problem
inside jit, so they may be traced scalars.

Validated with interpret=True against ref.pgd_epoch_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _pgd_kernel(delta_ref, eta_ref, pi_ref, pow_ref, tau_ref, price_ref,
                lo_ref, ub_ref, lr_ref, temp_ref, lame_ref, out_ref, *,
                iters, proj_iters):
    delta = delta_ref[...].astype(jnp.float32)
    eta = eta_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    pow_nom = pow_ref[...].astype(jnp.float32)
    tau24 = tau_ref[...].astype(jnp.float32)
    price = price_ref[...].astype(jnp.float32)
    lo = lo_ref[...].astype(jnp.float32)
    ub = ub_ref[...].astype(jnp.float32)
    lr = lr_ref[...].astype(jnp.float32)
    temp = temp_ref[...].astype(jnp.float32)          # (TC, 1) broadcast
    lambda_e = lame_ref[...].astype(jnp.float32)      # (TC, 1) broadcast

    def project(z):
        a = jnp.min(z, 1) - jnp.max(ub, 1)
        b = jnp.max(z, 1) - jnp.min(lo, 1)

        def pbody(i, ab):
            a, b = ab
            m = 0.5 * (a + b)
            f = jnp.sum(jnp.clip(z - m[:, None], lo, ub), axis=1)
            a = jnp.where(f > 0, m, a)
            b = jnp.where(f > 0, b, m)
            return a, b

        a, b = jax.lax.fori_loop(0, proj_iters, pbody, (a, b))
        nu = 0.5 * (a + b)
        return jnp.clip(z - nu[:, None], lo, ub)

    def body(i, d):
        pow_h = pow_nom + pi * d * tau24
        s = pow_h / temp
        s = s - jnp.max(s, axis=1, keepdims=True)
        e = jnp.exp(s)
        w = e / jnp.sum(e, axis=1, keepdims=True)
        grad = (lambda_e * eta + price * w) * pi * tau24
        return project(d - lr * grad)

    out_ref[...] = jax.lax.fori_loop(0, iters, body, delta).astype(
        out_ref.dtype)


def pgd_epoch_pallas(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr, *,
                     temp, lambda_e, iters: int, proj_iters: int = 50,
                     tile: int = DEFAULT_TILE, interpret: bool = False):
    """All matrices (n, H); tau24/price/lr (n, 1); temp/lambda_e scalar
    (float or traced). Returns new delta."""
    n, H = delta.shape
    tile = min(tile, n)
    pad = (-n) % tile

    def p2(x):
        return jnp.pad(x, ((0, pad), (0, 0)))

    temp_a = jnp.broadcast_to(jnp.asarray(temp, jnp.float32), (n, 1))
    lame_a = jnp.broadcast_to(jnp.asarray(lambda_e, jnp.float32), (n, 1))
    # pad temp with ones: the body divides by it in dead padded rows
    temp_a = jnp.pad(temp_a, ((0, pad), (0, 0)), constant_values=1.0)
    args = [p2(x) for x in (delta, eta, pi, pow_nom, tau24, price, lo, ub,
                            lr)] + [temp_a, p2(lame_a)]
    nt = (n + pad) // tile
    kernel = functools.partial(_pgd_kernel, iters=iters,
                               proj_iters=proj_iters)
    wide = pl.BlockSpec((tile, H), lambda i: (i, 0))
    slim = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[wide, wide, wide, wide, slim, slim, wide, wide, slim,
                  slim, slim],
        out_specs=wide,
        out_shape=jax.ShapeDtypeStruct((n + pad, H), delta.dtype),
        interpret=interpret,
    )(*args)
    return out[:n]
