"""Pallas TPU kernel: fused VCC projected-gradient epoch.

Tiling: grid = (n_clusters / TC,); each step loads a (TC, 24) cluster tile
(delta, eta, pi, pow_nom, lo, ub + per-cluster scalars) into VMEM and runs
the FULL inner optimization epoch — ``iters`` x [gradient of the linearized
carbon+peak objective → 50-step bisection projection onto the conservation
simplex slab] — without touching HBM between iterations. The day-ahead
optimizer calls this once per dual-ascent round for the whole fleet
(~O(100k) clusters x 24 h), so HBM round-trips per PGD iteration are the
hotspot being removed.

``temp`` and ``lambda_e`` ride in as broadcast (n, 1) operands rather than
compile-time constants: the day cycle derives ``temp`` from the problem
inside jit, so they may be traced scalars.

Validated with interpret=True against ref.pgd_epoch_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _project_rows(z, lo, ub, proj_iters):
    """Shared in-VMEM bisection projection onto {sum_h = 0} ∩ [lo, ub]
    (same math as ref.project_row; rows independent). The ONE copy both
    kernels call — the identical-members bitwise contract between the
    plain and ensemble epochs rides on them projecting identically."""
    a = jnp.min(z, 1) - jnp.max(ub, 1)
    b = jnp.max(z, 1) - jnp.min(lo, 1)

    def pbody(i, ab):
        a, b = ab
        m = 0.5 * (a + b)
        f = jnp.sum(jnp.clip(z - m[:, None], lo, ub), axis=1)
        a = jnp.where(f > 0, m, a)
        b = jnp.where(f > 0, b, m)
        return a, b

    a, b = jax.lax.fori_loop(0, proj_iters, pbody, (a, b))
    nu = 0.5 * (a + b)
    return jnp.clip(z - nu[:, None], lo, ub)


def _pgd_kernel(delta_ref, eta_ref, pi_ref, pow_ref, tau_ref, price_ref,
                lo_ref, ub_ref, lr_ref, temp_ref, lame_ref, out_ref, *,
                iters, proj_iters):
    delta = delta_ref[...].astype(jnp.float32)
    eta = eta_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    pow_nom = pow_ref[...].astype(jnp.float32)
    tau24 = tau_ref[...].astype(jnp.float32)
    price = price_ref[...].astype(jnp.float32)
    lo = lo_ref[...].astype(jnp.float32)
    ub = ub_ref[...].astype(jnp.float32)
    lr = lr_ref[...].astype(jnp.float32)
    temp = temp_ref[...].astype(jnp.float32)          # (TC, 1) broadcast
    lambda_e = lame_ref[...].astype(jnp.float32)      # (TC, 1) broadcast

    def body(i, d):
        pow_h = pow_nom + pi * d * tau24
        s = pow_h / temp
        s = s - jnp.max(s, axis=1, keepdims=True)
        e = jnp.exp(s)
        w = e / jnp.sum(e, axis=1, keepdims=True)
        grad = (lambda_e * eta + price * w) * pi * tau24
        return _project_rows(d - lr * grad, lo, ub, proj_iters)

    out_ref[...] = jax.lax.fori_loop(0, iters, body, delta).astype(
        out_ref.dtype)


def _pgd_ens_kernel(delta_ref, eta_ref, pi_ref, pow_ref, tau_ref, price_ref,
                    lo_ref, ub_ref, lr_ref, temp_ref, lame_ref, risk_ref,
                    out_ref, *, iters, proj_iters):
    """CVaR ensemble epoch: blocks carry a (K, TC, H) member tile of
    eta/pow_nom; the member axis is reduced IN-KERNEL (per-cluster
    soft-CVaR tilt, anchored on member 0 — mirrors ref.pgd_step_ens_arrays
    op for op, so identical members collapse bitwise)."""
    delta = delta_ref[...].astype(jnp.float32)          # (TC, H)
    eta_e = eta_ref[...].astype(jnp.float32)            # (K, TC, H)
    pi = pi_ref[...].astype(jnp.float32)
    pow_e = pow_ref[...].astype(jnp.float32)            # (K, TC, H)
    tau24 = tau_ref[...].astype(jnp.float32)            # (TC, 1)
    price = price_ref[...].astype(jnp.float32)
    lo = lo_ref[...].astype(jnp.float32)
    ub = ub_ref[...].astype(jnp.float32)
    lr = lr_ref[...].astype(jnp.float32)
    temp = temp_ref[...].astype(jnp.float32)            # (TC, 1) broadcast
    lambda_e = lame_ref[...].astype(jnp.float32)        # (TC, 1) broadcast
    risk_s = risk_ref[...].astype(jnp.float32)          # (TC, 1) broadcast

    def body(i, d):
        ph = pow_e + (pi * d * tau24)[None]             # (K, TC, H)
        s = ph / temp[None]
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        w_peak = e / jnp.sum(e, axis=-1, keepdims=True)
        cost = lambda_e[..., 0][None] * jnp.sum(eta_e * ph, axis=-1) \
            + price[..., 0][None] * jnp.sum(w_peak * ph, axis=-1)  # (K, TC)
        z = cost - cost[:1]
        dev = cost - jnp.mean(cost, axis=0, keepdims=True)
        scale = jnp.mean(jnp.abs(dev), axis=0, keepdims=True) + 1e-9
        t = risk_s[..., 0][None] * z / scale
        t = t - jnp.max(t, axis=0, keepdims=True)
        et = jnp.exp(t)
        wm = (et / jnp.sum(et, axis=0, keepdims=True))[..., None]
        eta_w = eta_e[0] + jnp.sum(wm * (eta_e - eta_e[:1]), axis=0)
        w_w = w_peak[0] + jnp.sum(wm * (w_peak - w_peak[:1]), axis=0)
        grad = (lambda_e * eta_w + price * w_w) * pi * tau24
        return _project_rows(d - lr * grad, lo, ub, proj_iters)

    out_ref[...] = jax.lax.fori_loop(0, iters, body, delta).astype(
        out_ref.dtype)


def pgd_epoch_pallas(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr, *,
                     temp, lambda_e, iters: int, proj_iters: int = 50,
                     tile: int = DEFAULT_TILE, interpret: bool = False):
    """All matrices (n, H); tau24/price/lr (n, 1); temp/lambda_e scalar
    (float or traced). Returns new delta."""
    n, H = delta.shape
    tile = min(tile, n)
    pad = (-n) % tile

    def p2(x):
        return jnp.pad(x, ((0, pad), (0, 0)))

    temp_a = jnp.broadcast_to(jnp.asarray(temp, jnp.float32), (n, 1))
    lame_a = jnp.broadcast_to(jnp.asarray(lambda_e, jnp.float32), (n, 1))
    # pad temp with ones: the body divides by it in dead padded rows
    temp_a = jnp.pad(temp_a, ((0, pad), (0, 0)), constant_values=1.0)
    args = [p2(x) for x in (delta, eta, pi, pow_nom, tau24, price, lo, ub,
                            lr)] + [temp_a, p2(lame_a)]
    nt = (n + pad) // tile
    kernel = functools.partial(_pgd_kernel, iters=iters,
                               proj_iters=proj_iters)
    wide = pl.BlockSpec((tile, H), lambda i: (i, 0))
    slim = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[wide, wide, wide, wide, slim, slim, wide, wide, slim,
                  slim, slim],
        out_specs=wide,
        out_shape=jax.ShapeDtypeStruct((n + pad, H), delta.dtype),
        interpret=interpret,
    )(*args)
    return out[:n]


def _joint_kernel(d_ref, s_ref, eta_ref, pi_ref, pow_ref, tau_ref, uif_ref,
                  uifq_ref, ratio_ref, upow_ref, cap_ref, price_ref, lr_ref,
                  temp_ref, lame_ref, dout_ref, gs_ref, *, drop_limit,
                  proj_iters):
    """Fused joint spatio-temporal step (mirrors ref.joint_step_arrays op
    for op): recompute the temporal bounds from the shifted budget
    tau + s, take the linearized carbon + softmax-peak gradient at the
    shifted point, project delta exactly, and emit the per-cluster shift
    gradient. The fleet-coupled s projection (sum_c s = 0) happens
    outside the cluster-tiled grid."""
    d = d_ref[...].astype(jnp.float32)               # (TC, H)
    s = s_ref[...].astype(jnp.float32)               # (TC, 1)
    eta = eta_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    pow_nom = pow_ref[...].astype(jnp.float32)
    tau = tau_ref[...].astype(jnp.float32)           # (TC, 1)
    u_if = uif_ref[...].astype(jnp.float32)
    u_if_q = uifq_ref[...].astype(jnp.float32)
    ratio = ratio_ref[...].astype(jnp.float32)
    u_pow_cap = upow_ref[...].astype(jnp.float32)    # (TC, 1)
    capacity = cap_ref[...].astype(jnp.float32)      # (TC, 1)
    price = price_ref[...].astype(jnp.float32)       # (TC, 1)
    lr_d = lr_ref[...].astype(jnp.float32)           # (TC, 1)
    temp = temp_ref[...].astype(jnp.float32)         # (TC, 1) broadcast
    lambda_e = lame_ref[...].astype(jnp.float32)     # (TC, 1) broadcast

    tau_s = tau + s
    t24 = jnp.clip(tau_s / 24.0, 1e-9, None)
    ub = jnp.minimum((u_pow_cap - u_if_q) / t24 - 1.0,
                     (capacity / ratio - u_if) / t24 - 1.0)
    ub = jnp.clip(ub, -drop_limit, 24.0)
    feas = (jnp.sum(ub, axis=1, keepdims=True) >= 0.0) \
        & (tau_s > 1e-6) \
        & jnp.all(ub > -drop_limit + 1e-9, axis=1, keepdims=True)
    lo = jnp.where(feas, jnp.full_like(ub, -drop_limit), 0.0)
    ub = jnp.where(feas, ub, 0.0)

    pow_h = pow_nom + pi * (d * tau_s + s) / 24.0
    z = pow_h / temp
    z = z - jnp.max(z, axis=1, keepdims=True)
    e = jnp.exp(z)
    w = e / jnp.sum(e, axis=1, keepdims=True)
    gcoef = (lambda_e * eta + price * w) * pi
    g_d = gcoef * (tau_s / 24.0)
    g_s = jnp.sum(gcoef * (1.0 + d), axis=1, keepdims=True) / 24.0
    d2 = _project_rows(d - lr_d * g_d, lo, ub, proj_iters)
    dout_ref[...] = d2.astype(dout_ref.dtype)
    gs_ref[...] = g_s.astype(gs_ref.dtype)


def joint_step_pallas(delta, s, eta, pi, pow_nom, tau, u_if, u_if_q, ratio,
                      u_pow_cap, capacity, price, lr_d, *, temp, lambda_e,
                      drop_limit: float, proj_iters: int = 50,
                      tile: int = DEFAULT_TILE, interpret: bool = False):
    """Wide operands (n, H); slim operands (n, 1); temp/lambda_e scalar
    (float or traced); drop_limit static. Returns (delta', g_s (n, 1))."""
    n, H = delta.shape
    tile = min(tile, n)
    pad = (-n) % tile

    def p2(x, fill=0.0):
        return jnp.pad(x, ((0, pad), (0, 0)), constant_values=fill)

    def scal(v, fill=0.0):
        a = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n, 1))
        return jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)

    args = [p2(delta), p2(s), p2(eta), p2(pi), p2(pow_nom), p2(tau),
            p2(u_if), p2(u_if_q),
            p2(ratio, fill=1.0),       # dead rows divide by ratio
            p2(u_pow_cap), p2(capacity), p2(price), p2(lr_d),
            scal(temp, fill=1.0),      # dead rows divide by temp
            scal(lambda_e)]
    nt = (n + pad) // tile
    kernel = functools.partial(_joint_kernel, drop_limit=drop_limit,
                               proj_iters=proj_iters)
    wide = pl.BlockSpec((tile, H), lambda i: (i, 0))
    slim = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    d2, g_s = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[wide, slim, wide, wide, wide, slim, wide, wide, wide,
                  slim, slim, slim, slim, slim, slim],
        out_specs=(wide, slim),
        out_shape=(jax.ShapeDtypeStruct((n + pad, H), delta.dtype),
                   jax.ShapeDtypeStruct((n + pad, 1), jnp.float32)),
        interpret=interpret,
    )(*args)
    return d2[:n], g_s[:n]


ENS_TILE = 64     # smaller cluster tile: each block also carries K members


def pgd_epoch_ens_pallas(delta, eta_e, pi, pow_nom_e, tau24, price, lo, ub,
                         lr, *, temp, lambda_e, risk_s, iters: int,
                         proj_iters: int = 50, tile: int = ENS_TILE,
                         interpret: bool = False):
    """CVaR ensemble epoch. eta_e/pow_nom_e: (K, n, H) member stacks;
    the rest as in ``pgd_epoch_pallas``; ``risk_s`` scalar (float or
    traced) soft-CVaR sharpness (0 = risk-neutral). The grid tiles the
    cluster axis only — every block loads its full K-member slab into VMEM
    and reduces the member axis in-kernel (K x (tile, H) fits VMEM for the
    sweep sizes K <= 32, tile = 64)."""
    K, n, H = eta_e.shape
    tile = min(tile, n)
    pad = (-n) % tile

    def p2(x):
        return jnp.pad(x, ((0, pad), (0, 0)))

    def p3(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0)))

    def scal(v, fill=0.0):
        a = jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n, 1))
        return jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)

    args = [p2(delta), p3(eta_e), p2(pi), p3(pow_nom_e), p2(tau24),
            p2(price), p2(lo), p2(ub), p2(lr),
            scal(temp, fill=1.0),      # body divides by temp in dead rows
            scal(lambda_e), scal(risk_s)]
    nt = (n + pad) // tile
    kernel = functools.partial(_pgd_ens_kernel, iters=iters,
                               proj_iters=proj_iters)
    wide = pl.BlockSpec((tile, H), lambda i: (i, 0))
    slim = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    ens = pl.BlockSpec((K, tile, H), lambda i: (0, i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[wide, ens, wide, ens, slim, slim, wide, wide, slim,
                  slim, slim, slim],
        out_specs=wide,
        out_shape=jax.ShapeDtypeStruct((n + pad, H), delta.dtype),
        interpret=interpret,
    )(*args)
    return out[:n]
