"""Dispatching wrapper for the fused VCC PGD epoch.

Same convention as the other kernel packages (``flash_attention``,
``linear_scan``): ``use_pallas=None`` auto-selects the Pallas kernel on TPU
and the jnp oracle elsewhere; ``interpret=True`` forces the kernel through
the Pallas interpreter (CPU parity tests). ``core.vcc.solve_vcc`` routes its
inner loop here for BOTH the legacy fleet path and the sim engine.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.vcc_pgd import ref as _ref


def _tpu_available() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def pgd_epoch(prob, delta, mu, lo, ub, lr_eff, temp, iters,
              use_pallas: Optional[bool] = None, interpret: bool = False):
    """Adapter from a repro.core.vcc.VCCProblem to the kernel layout.

    ``temp`` and ``prob.lambda_e`` may be traced scalars (the day cycle
    computes temp from the problem inside jit/vmap). Problems carrying
    ensemble axes (``prob.eta_ens``/``prob.pow_nom_ens`` not None, K > 1)
    route to the CVaR ensemble epoch, which reduces the member axis
    in-kernel; plain problems keep the exact legacy epoch graph.
    """
    tau24 = (prob.tau[:, None] / 24.0).astype(jnp.float32)
    price = (prob.lambda_p + mu[prob.campus])[:, None].astype(jnp.float32)
    lr = jnp.broadcast_to(jnp.asarray(lr_eff, jnp.float32),
                          (delta.shape[0], 1)) \
        if jnp.ndim(lr_eff) < 2 else lr_eff.astype(jnp.float32)
    kw = dict(temp=temp, lambda_e=prob.lambda_e, iters=int(iters))
    if use_pallas is None:
        use_pallas = _tpu_available()
    if getattr(prob, "eta_ens", None) is not None:
        kw["risk_s"] = _ref.cvar_sharpness(prob.risk_beta)
        if use_pallas or interpret:
            from repro.kernels.vcc_pgd import kernel as _kernel
            return _kernel.pgd_epoch_ens_pallas(
                delta, prob.eta_ens, prob.pi, prob.pow_nom_ens, tau24,
                price, lo, ub, lr, interpret=interpret, **kw)
        return _ref.pgd_epoch_ens_ref(delta, prob.eta_ens, prob.pi,
                                      prob.pow_nom_ens, tau24, price, lo,
                                      ub, lr, **kw)
    if use_pallas or interpret:
        from repro.kernels.vcc_pgd import kernel as _kernel
        return _kernel.pgd_epoch_pallas(
            delta, prob.eta, prob.pi, prob.pow_nom, tau24, price, lo, ub,
            lr, interpret=interpret, **kw)
    return _ref.pgd_epoch_ref(delta, prob.eta, prob.pi, prob.pow_nom, tau24,
                              price, lo, ub, lr, **kw)


def joint_step(prob, delta, s, mu, lr_d, temp,
               use_pallas: Optional[bool] = None, interpret: bool = False):
    """One fused JOINT spatio-temporal step for a VCCProblem: temporal
    bounds recomputed from the shifted budget tau + s, delta gradient +
    exact projection, and the per-cluster shift gradient g_s (n, 1) as a
    second output (the fleet-coupled s projection happens in
    ``core.solver.joint_epochs``). Same dispatch convention as
    ``pgd_epoch``; ``temp``/``prob.lambda_e`` may be traced scalars."""
    f32 = jnp.float32
    n = delta.shape[0]
    price = (prob.lambda_p + mu[prob.campus])[:, None].astype(f32)
    lr = jnp.broadcast_to(jnp.asarray(lr_d, f32), (n, 1)) \
        if jnp.ndim(lr_d) < 2 else lr_d.astype(f32)
    sv = s[:, None].astype(f32)
    tau = prob.tau[:, None].astype(f32)
    u_pow_cap = prob.u_pow_cap[:, None].astype(f32)
    capacity = prob.capacity[:, None].astype(f32)
    kw = dict(temp=temp, lambda_e=prob.lambda_e,
              drop_limit=float(prob.drop_limit))
    if use_pallas is None:
        use_pallas = _tpu_available()
    if use_pallas or interpret:
        from repro.kernels.vcc_pgd import kernel as _kernel
        return _kernel.joint_step_pallas(
            delta, sv, prob.eta, prob.pi, prob.pow_nom, tau, prob.u_if,
            prob.u_if_q, prob.ratio, u_pow_cap, capacity, price, lr,
            interpret=interpret, **kw)
    return _ref.joint_step_arrays(
        delta, sv, prob.eta, prob.pi, prob.pow_nom, tau, prob.u_if,
        prob.u_if_q, prob.ratio, u_pow_cap, capacity, price, lr, **kw)
