"""jnp oracle for the fused VCC projected-gradient epoch (paper §III-C).

One epoch = ``iters`` iterations of [linearized-objective gradient →
exact bisection projection onto {sum_h delta = 0} ∩ [lo, ub]] for a tile of
clusters. This module is the SINGLE implementation of that math:
``core.vcc`` delegates its ``project_conservation`` / ``pgd_step`` to
``project_row`` / ``pgd_step_arrays``, and the Pallas kernel mirrors the
same ops in VMEM. ``temp`` / ``lambda_e`` may be Python floats or traced
scalars (the day-cycle computes ``temp`` from the problem inside jit).

Ensemble (CVaR) variant: ``pgd_step_ens_arrays`` / ``pgd_epoch_ens_ref``
take K member realizations of (eta, pow_nom) and descend a per-cluster
soft-CVaR tilt of the member costs (see ``repro.core.risk`` for the risk
model). The member reduction is *anchored on member 0*:

    x_w = x[0] + sum_k w_k * (x[k] - x[0])        (== sum_k w_k x[k])

so K identical members collapse BITWISE to the single-member gradient
(every deviation is exactly 0.0), which is the degenerate-ensemble parity
contract tested in tests/test_risk.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32

# softmax sharpness at risk_beta=0.5 (costs are normalized to unit mean
# absolute deviation before the tilt, so this is dimensionless)
CVAR_SHARPNESS = 4.0


def cvar_sharpness(beta):
    """Map the CVaR tail fraction ``beta`` to the soft-tilt sharpness.

    Convention (repro.core.risk): the risk objective averages the worst
    ``beta`` fraction of member outcomes — ``beta -> 1`` is the risk-
    neutral mean (sharpness 0, today's point-forecast path), smaller beta
    is more risk-averse (sharpness -> inf concentrates on the worst
    member). ``beta`` may be a Python float or a traced scalar.
    """
    b = jnp.clip(jnp.asarray(beta, f32), 0.05, 1.0)
    return CVAR_SHARPNESS * (1.0 - b) / b


def project_row(z, lo, ub, iters: int = 50):
    """Bisection projection onto {sum_h = 0} ∩ [lo, ub], rows independent.
    z/lo/ub: (n, H). Elementwise + ordered ops only: bitwise batch-invariant
    (the sim engine's batched==sequential parity contract rides on this)."""
    a = jnp.min(z, 1) - jnp.max(ub, 1)
    b = jnp.max(z, 1) - jnp.min(lo, 1)

    def body(i, ab):
        a, b = ab
        m = 0.5 * (a + b)
        f = jnp.sum(jnp.clip(z - m[:, None], lo, ub), axis=1)
        a = jnp.where(f > 0, m, a)
        b = jnp.where(f > 0, b, m)
        return a, b

    a, b = jax.lax.fori_loop(0, iters, body, (a, b))
    nu = 0.5 * (a + b)
    return jnp.clip(z - nu[:, None], lo, ub)


def pgd_step_arrays(d, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                    temp, lambda_e, proj_iters: int = 50):
    """One projected-gradient step in the kernel's array layout.

    d/eta/pi/pow_nom/lo/ub: (n, H); tau24/price/lr: (n, 1); temp/lambda_e:
    scalars (possibly traced). The linearized carbon + softmax-peak gradient
    followed by the exact conservation projection.
    """
    pow_h = pow_nom + pi * d * tau24
    w = jax.nn.softmax(pow_h / temp, axis=1)
    grad = (lambda_e * eta + price * w) * pi * tau24
    return project_row(d - lr * grad, lo, ub, proj_iters)


def pgd_epoch_ref(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                  *, temp, lambda_e, iters: int, proj_iters: int = 50):
    """delta/eta/pi/pow_nom/lo/ub: (n, H); tau24/price/lr: (n, 1)."""

    def body(i, d):
        return pgd_step_arrays(d, eta, pi, pow_nom, tau24, price, lo, ub,
                               lr, temp, lambda_e, proj_iters)

    return jax.lax.fori_loop(0, iters, body, delta)


# ------------------------------------------------- ensemble (CVaR) variant

def member_costs(d, eta_e, pi, pow_nom_e, tau24, price, temp, lambda_e):
    """Per-(member, cluster) day cost under delta ``d``.

    eta_e/pow_nom_e: (K, n, H) member realizations; d/pi: (n, H);
    tau24/price: (n, 1). Returns (cost (K, n), pow_e (K, n, H),
    w_peak (K, n, H)) — the softmax-peak weights are reused by the
    gradient so the step computes each member's forward pass once.
    """
    pow_e = pow_nom_e + (pi * d * tau24)[None]
    w_peak = jax.nn.softmax(pow_e / temp, axis=-1)
    cost = lambda_e * jnp.sum(eta_e * pow_e, axis=-1) \
        + price[..., 0] * jnp.sum(w_peak * pow_e, axis=-1)
    return cost, pow_e, w_peak


def cvar_member_weights(cost, risk_s):
    """Soft-CVaR member weights per cluster. cost: (K, n); risk_s: scalar
    (possibly traced; 0 = uniform/risk-neutral). Logits are anchored on
    member 0 — identical members give EXACTLY zero logits (and uniform
    weights) under any reduction order, which mean-centering cannot
    guarantee — while the normalizing scale is the mean absolute
    deviation from the member mean, the SAME scale ``risk.soft_cvar``
    uses, so the step's tilt sharpness matches the reported objective
    (softmax is shift-invariant, so anchor vs mean only moves logits by a
    constant)."""
    z = cost - cost[:1]
    dev = cost - jnp.mean(cost, axis=0, keepdims=True)
    scale = jnp.mean(jnp.abs(dev), axis=0, keepdims=True) + 1e-9
    return jax.nn.softmax(risk_s * z / scale, axis=0)


def pgd_step_ens_arrays(d, eta_e, pi, pow_nom_e, tau24, price, lo, ub, lr,
                        temp, lambda_e, risk_s, proj_iters: int = 50):
    """One CVaR-aware projected-gradient step over a K-member ensemble.

    Danskin-style: member weights are treated as locally constant, so the
    descent direction is the weight-tilted member gradient. The member
    reduction is anchored on member 0 (see module docstring) so identical
    members reproduce ``pgd_step_arrays`` bitwise.
    """
    cost, pow_e, w_peak = member_costs(d, eta_e, pi, pow_nom_e, tau24,
                                       price, temp, lambda_e)
    wm = cvar_member_weights(cost, risk_s)[..., None]        # (K, n, 1)
    eta_w = eta_e[0] + jnp.sum(wm * (eta_e - eta_e[:1]), axis=0)
    w_w = w_peak[0] + jnp.sum(wm * (w_peak - w_peak[:1]), axis=0)
    grad = (lambda_e * eta_w + price * w_w) * pi * tau24
    return project_row(d - lr * grad, lo, ub, proj_iters)


def pgd_epoch_ens_ref(delta, eta_e, pi, pow_nom_e, tau24, price, lo, ub,
                      lr, *, temp, lambda_e, risk_s, iters: int,
                      proj_iters: int = 50):
    """eta_e/pow_nom_e: (K, n, H); delta/pi/lo/ub: (n, H);
    tau24/price/lr: (n, 1); temp/lambda_e/risk_s scalars (maybe traced)."""

    def body(i, d):
        return pgd_step_ens_arrays(d, eta_e, pi, pow_nom_e, tau24, price,
                                   lo, ub, lr, temp, lambda_e, risk_s,
                                   proj_iters)

    return jax.lax.fori_loop(0, iters, body, delta)


# ------------------------------------------- joint spatio-temporal variant

def joint_step_arrays(d, s, eta, pi, pow_nom, tau, u_if, u_if_q, ratio,
                      u_pow_cap, capacity, price, lr_d, temp, lambda_e,
                      drop_limit: float, proj_iters: int = 50):
    """One fused JOINT spatio-temporal step in the kernel layout.

    d/eta/pi/pow_nom/u_if/u_if_q/ratio: (n, H); s/tau/u_pow_cap/capacity/
    price/lr_d: (n, 1); temp/lambda_e: scalars (possibly traced);
    drop_limit: static float. Everything per-cluster is fused: the
    temporal bounds lo/ub are RECOMPUTED from the shifted budget
    tau + s (the same formulas as ``core.vcc.delta_bounds``, including
    the feasibility mask that collapses hopeless clusters to {0}), the
    linearized carbon + softmax-peak gradient is taken at the shifted
    point — power = pow_nom + pi * (d * (tau+s) + s) / 24, which keeps
    the baseline pi*s/24 term of moving the flat budget itself — and
    delta is projected exactly onto its conservation slab.

    Returns (d', g_s): the updated delta tile and the per-cluster shift
    gradient (n, 1). The s update itself conserves over ALL clusters
    (sum_c s = 0), so it cannot be tiled and happens outside
    (``core.solver.joint_epochs``).
    """
    tau_s = tau + s
    t24 = jnp.clip(tau_s / 24.0, 1e-9, None)
    ub = jnp.minimum((u_pow_cap - u_if_q) / t24 - 1.0,
                     (capacity / ratio - u_if) / t24 - 1.0)
    ub = jnp.clip(ub, -drop_limit, 24.0)
    feas = (jnp.sum(ub, axis=1, keepdims=True) >= 0.0) \
        & (tau_s > 1e-6) \
        & jnp.all(ub > -drop_limit + 1e-9, axis=1, keepdims=True)
    lo = jnp.where(feas, jnp.full_like(ub, -drop_limit), 0.0)
    ub = jnp.where(feas, ub, 0.0)

    pow_h = pow_nom + pi * (d * tau_s + s) / 24.0
    w = jax.nn.softmax(pow_h / temp, axis=1)
    gcoef = (lambda_e * eta + price * w) * pi
    g_d = gcoef * (tau_s / 24.0)
    g_s = jnp.sum(gcoef * (1.0 + d), axis=1, keepdims=True) / 24.0
    d2 = project_row(d - lr_d * g_d, lo, ub, proj_iters)
    return d2, g_s
