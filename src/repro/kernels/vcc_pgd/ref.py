"""jnp oracle for the fused VCC projected-gradient epoch (paper §III-C).

One epoch = ``iters`` iterations of [linearized-objective gradient →
exact bisection projection onto {sum_h delta = 0} ∩ [lo, ub]] for a tile of
clusters. This module is the SINGLE implementation of that math:
``core.vcc`` delegates its ``project_conservation`` / ``pgd_step`` to
``project_row`` / ``pgd_step_arrays``, and the Pallas kernel mirrors the
same ops in VMEM. ``temp`` / ``lambda_e`` may be Python floats or traced
scalars (the day-cycle computes ``temp`` from the problem inside jit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def project_row(z, lo, ub, iters: int = 50):
    """Bisection projection onto {sum_h = 0} ∩ [lo, ub], rows independent.
    z/lo/ub: (n, H). Elementwise + ordered ops only: bitwise batch-invariant
    (the sim engine's batched==sequential parity contract rides on this)."""
    a = jnp.min(z, 1) - jnp.max(ub, 1)
    b = jnp.max(z, 1) - jnp.min(lo, 1)

    def body(i, ab):
        a, b = ab
        m = 0.5 * (a + b)
        f = jnp.sum(jnp.clip(z - m[:, None], lo, ub), axis=1)
        a = jnp.where(f > 0, m, a)
        b = jnp.where(f > 0, b, m)
        return a, b

    a, b = jax.lax.fori_loop(0, iters, body, (a, b))
    nu = 0.5 * (a + b)
    return jnp.clip(z - nu[:, None], lo, ub)


def pgd_step_arrays(d, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                    temp, lambda_e, proj_iters: int = 50):
    """One projected-gradient step in the kernel's array layout.

    d/eta/pi/pow_nom/lo/ub: (n, H); tau24/price/lr: (n, 1); temp/lambda_e:
    scalars (possibly traced). The linearized carbon + softmax-peak gradient
    followed by the exact conservation projection.
    """
    pow_h = pow_nom + pi * d * tau24
    w = jax.nn.softmax(pow_h / temp, axis=1)
    grad = (lambda_e * eta + price * w) * pi * tau24
    return project_row(d - lr * grad, lo, ub, proj_iters)


def pgd_epoch_ref(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                  *, temp, lambda_e, iters: int, proj_iters: int = 50):
    """delta/eta/pi/pow_nom/lo/ub: (n, H); tau24/price/lr: (n, 1)."""

    def body(i, d):
        return pgd_step_arrays(d, eta, pi, pow_nom, tau24, price, lo, ub,
                               lr, temp, lambda_e, proj_iters)

    return jax.lax.fori_loop(0, iters, body, delta)
