"""jnp oracle for the fused VCC projected-gradient epoch (paper §III-C).

One epoch = ``iters`` iterations of [linearized-objective gradient →
exact bisection projection onto {sum_h delta = 0} ∩ [lo, ub]] for a tile of
clusters. This is the math executed per day for every cluster fleetwide;
the Pallas kernel keeps the whole epoch in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def project_row(z, lo, ub, iters: int = 50):
    """Bisection projection, rows independent. z/lo/ub: (n, H)."""
    a = jnp.min(z, 1) - jnp.max(ub, 1)
    b = jnp.max(z, 1) - jnp.min(lo, 1)

    def body(i, ab):
        a, b = ab
        m = 0.5 * (a + b)
        f = jnp.sum(jnp.clip(z - m[:, None], lo, ub), axis=1)
        a = jnp.where(f > 0, m, a)
        b = jnp.where(f > 0, b, m)
        return a, b

    a, b = jax.lax.fori_loop(0, iters, body, (a, b))
    nu = 0.5 * (a + b)
    return jnp.clip(z - nu[:, None], lo, ub)


def pgd_epoch_ref(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                  *, temp: float, lambda_e: float, iters: int,
                  proj_iters: int = 50):
    """delta/eta/pi/pow_nom/lo/ub: (n, H); tau24/price/lr: (n, 1)."""

    def body(i, d):
        pow_h = pow_nom + pi * d * tau24
        w = jax.nn.softmax(pow_h / temp, axis=1)
        grad = (lambda_e * eta + price * w) * pi * tau24
        return project_row(d - lr * grad, lo, ub, proj_iters)

    return jax.lax.fori_loop(0, iters, body, delta)
