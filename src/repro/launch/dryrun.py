import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell: lower + compile the step
function on the production mesh with ShapeDtypeStruct inputs (no allocation),
record memory_analysis / cost_analysis / collective schedule, and derive the
three roofline terms. Results land in benchmarks/results/dryrun/*.json.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, SHAPES, get_arch
from repro.launch import roofline as rl
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import (batch_specs, build_model, cache_specs, decode_specs,
                          param_specs)
from repro.optim import AdamWConfig, init_opt_state
from repro.sharding import (batch_pspecs, cache_pspecs, opt_pspecs,
                            param_pspecs, shardings)
from repro.sharding.act import activation_sharding
from repro.training import make_prefill_step, make_serve_step, make_train_step

from jax.sharding import PartitionSpec as P, NamedSharding

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" \
    / "dryrun"


def _spec_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               overrides=None, mesh_shape=None):
    """Lower+compile one cell; returns (compiled, lowered, meta).

    mesh_shape: optional (dp, tp) logical reshape of the single-pod 256
    chips for §Perf sharding iterations (the baseline mesh is 16x16)."""
    arch = get_arch(arch_name)
    cfg = arch.config
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    if mesh_shape is not None:
        assert not multi_pod
        dp, tp = mesh_shape
        assert dp * tp == 256, "single-pod perf runs keep 256 chips"
        mesh = jax.make_mesh((dp, tp), ("data", "model"),
                             devices=jax.devices()[:256])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    with use_mesh(mesh), activation_sharding(mesh):
        return _lower_cell_inner(cfg, shape, mesh, multi_pod)


def _lower_cell_inner(cfg, shape, mesh, multi_pod):
    model = build_model(cfg)
    p_specs = param_specs(cfg)
    p_sh = shardings(param_pspecs(cfg, p_specs, mesh), mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        o_specs = jax.eval_shape(lambda: init_opt_state(p_specs, opt_cfg))
        o_sh = shardings(
            opt_pspecs(cfg, param_pspecs(cfg, o_specs, mesh), mesh), mesh)
        b_specs = batch_specs(cfg, shape)
        b_sh = shardings(batch_pspecs(b_specs, mesh), mesh)
        step = make_train_step(model, opt_cfg)
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1)).lower(
            _spec_tree(p_specs), _spec_tree(o_specs), _spec_tree(b_specs))
    elif shape.kind == "prefill":
        b_specs = batch_specs(cfg, shape)
        b_sh = shardings(batch_pspecs(b_specs, mesh), mesh)
        from repro.sharding.partition import batch_entry
        c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
        c_sh = shardings(cache_pspecs(cfg, c_specs, mesh), mesh)
        logits_sh = NamedSharding(
            mesh, P(batch_entry(mesh, shape.global_batch), None))
        step = make_prefill_step(model, shape.seq_len)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                          out_shardings=(logits_sh, c_sh)).lower(
            _spec_tree(p_specs), _spec_tree(b_specs))
    else:  # decode
        from repro.sharding.partition import batch_entry
        c_specs, tok_spec, pos_spec = decode_specs(cfg, shape)
        c_ps = cache_pspecs(cfg, c_specs, mesh)
        c_sh = shardings(c_ps, mesh)
        ba = batch_entry(mesh, shape.global_batch)
        tok_sh = NamedSharding(mesh, P(ba))
        pos_sh = NamedSharding(mesh, P())
        logits_sh = NamedSharding(mesh, P(ba, None))
        step = make_serve_step(model)
        lowered = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                          out_shardings=(logits_sh, c_sh),
                          donate_argnums=(1,)).lower(
            _spec_tree(p_specs), c_specs, tok_spec, pos_spec)
    compiled = lowered.compile()
    return compiled, lowered, {"cfg": cfg, "shape": shape,
                               "p_specs": p_specs, "mesh": mesh}


def analyze(compiled, meta, multi_pod: bool, elapsed: float):
    cfg, shape, p_specs = meta["cfg"], meta["shape"], meta["p_specs"]
    chips = 512 if multi_pod else 256
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware static analysis (XLA CPU cost_analysis counts while bodies
    # once and reports unfused traffic — see hlo_analysis.py)
    s = analyze_hlo(hlo)
    flops_dev = s.dot_flops
    # HBM-traffic proxy: dot operand/output traffic (perfect elementwise
    # fusion) + per-step argument/output IO (params, opt state, caches)
    bytes_dev = (s.dot_bytes + ma.argument_size_in_bytes
                 + ma.output_size_in_bytes)
    csum = {"by_op": s.collectives,
            "effective_bytes": s.collective_effective_bytes,
            "effective_bytes_bf16adj": s.collective_effective_bytes_bf16adj,
            "loops": s.loops[:40]}
    terms = rl.roofline_terms(flops_dev, bytes_dev, csum["effective_bytes"])
    terms["collective_s_bf16adj"] = (s.collective_effective_bytes_bf16adj
                                     / rl.ICI_BW)
    mflops = rl.model_flops(cfg, shape, p_specs)
    hlo_flops_global = flops_dev * chips
    n_total = rl.tree_param_count(p_specs)
    n_active = rl.active_param_count(cfg, p_specs)
    return {
        "arch": cfg.name, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind,
        "compile_s": round(elapsed, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            "fits_16g": (ma.argument_size_in_bytes - ma.alias_size_in_bytes
                         + ma.temp_size_in_bytes
                         + ma.output_size_in_bytes) < 16e9,
        },
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "xla_cost_analysis": {k: ca.get(k) for k in ("flops",
                                                     "bytes accessed")},
        "hlo_flops_global": hlo_flops_global,
        "model_flops_global": mflops,
        "useful_flop_ratio": (mflops / hlo_flops_global
                              if hlo_flops_global else None),
        "params_total": n_total,
        "params_active": n_active,
        "collectives": csum,
        "roofline": terms,
    }


def run_cell(arch_name, shape_name, multi_pod, out_dir: Path,
             overrides=None, tag="", mesh_shape=None):
    key = f"{arch_name}_{shape_name}_{'multipod' if multi_pod else 'pod'}"
    if tag:
        key += f"_{tag}"
    arch = get_arch(arch_name)
    if shape_name in arch.skip_shapes:
        rec = {"arch": arch_name, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "skipped": arch.skip_shapes[shape_name]}
        _save(out_dir, key, rec)
        print(f"[skip] {key}: {arch.skip_shapes[shape_name][:60]}...")
        return rec
    t0 = time.time()
    try:
        compiled, lowered, meta = lower_cell(arch_name, shape_name,
                                             multi_pod, overrides,
                                             mesh_shape)
        rec = analyze(compiled, meta, multi_pod, time.time() - t0)
        _save(out_dir, key, rec)
        r = rec["roofline"]
        print(f"[ok]   {key}: compile={rec['compile_s']}s "
              f"dominant={r['dominant']} "
              f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
              f"coll={r['collective_s']:.3e}s "
              f"frac={r['roofline_fraction']:.2f} "
              f"fits={rec['memory']['fits_16g']}")
        return rec
    except Exception as e:  # noqa: BLE001 - record failures per cell
        rec = {"arch": arch_name, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        _save(out_dir, key, rec)
        print(f"[FAIL] {key}: {type(e).__name__}: {str(e)[:200]}")
        return rec


def _save(out_dir: Path, key: str, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{key}.json").write_text(json.dumps(rec, indent=1,
                                                    default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--dp", type=int, default=0,
                    help="perf iteration: logical mesh reshape (dp, tp)")
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    out_dir = Path(args.out)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not args.all and not (args.arch or args.shape):
        ap.error("pass --arch/--shape or --all")
    mesh_shape = (args.dp, args.tp) if args.dp else None
    for mp in meshes:
        for a in archs:
            for s in shapes:
                key = f"{a}_{s}_{'multipod' if mp else 'pod'}"
                if args.tag:
                    key += f"_{args.tag}"
                if args.skip_existing and (out_dir / f"{key}.json").exists():
                    continue
                run_cell(a, s, mp, out_dir, tag=args.tag,
                         mesh_shape=mesh_shape)


if __name__ == "__main__":
    main()
