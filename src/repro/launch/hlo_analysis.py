"""Static cost analysis of compiled (post-SPMD, per-device) HLO text.

Why: XLA:CPU's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE and
reports unfused byte traffic, so neither FLOPs nor bytes are usable for a
TPU roofline when models scan over layers. This module walks the HLO call
graph from ENTRY, multiplying costs through ``while`` trip counts (extracted
from loop-condition constants), ``fusion``/``call`` bodies, and accumulating:

* ``dot_flops``   — 2 * prod(output dims) * prod(contracting dims) per dot
* ``dot_bytes``   — lhs + rhs + out bytes per dot (HBM-traffic proxy under
                    perfect elementwise fusion)
* collectives     — per-op counts/bytes with ring-effective per-device bytes

All quantities are per-device (the compiled module is the per-device SPMD
program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<op>[\w-]+)\((?P<args>.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-_]+)\s*"
                        r"\((?P<params>.*)\)\s*->")
_ARRAY_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-_]+)")
_COND_BODY_RE = re.compile(
    r"condition=%?([\w\.\-_]+),\s*body=%?([\w\.\-_]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _array_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    """All array components of a (possibly tuple) type string."""
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group("dims").split(",") if d]
        out.append((dt, dims))
    return out


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _array_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _split_params(sig: str) -> Dict[str, str]:
    """'a: f32[2], b: (s32[], f32[4])' -> {a: 'f32[2]', b: '(...)'}"""
    out = {}
    depth = 0
    cur = []
    parts = []
    for ch in sig:
        if ch == "(" :
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    for part in parts:
        if ":" not in part:
            continue
        name, t = part.split(":", 1)
        out[name.strip().lstrip("%")] = t.strip()
    return out


@dataclass
class Op:
    name: str
    type: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str]
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _HEADER_RE.match(line.strip())
            if m:
                cur = Computation(m.group("name"),
                                  _split_params(m.group("params")))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group("name"), m.group("type"), m.group("op"), line)
            cur.ops.append(op)
            cur.symbols[op.name] = op.type
    return comps, entry


def _resolve_shape(comp: Computation, operand: str) -> Optional[str]:
    operand = operand.strip()
    if "%" in operand and not operand.startswith("%"):
        # inline-typed operand ('f32[2,3]{1,0} %name'): the type is right
        # there — newer XLA prints operand types in the instruction line.
        tpart = operand.rsplit("%", 1)[0].strip()
        if _ARRAY_RE.search(tpart):
            return tpart
        operand = "%" + operand.rsplit("%", 1)[1]
    operand = operand.lstrip("%").strip()
    if operand in comp.symbols:
        return comp.symbols[operand]
    return comp.params.get(operand)


def _operands(args: str) -> List[str]:
    """Split the operand list of 'op(...)'. Operands may be bare names
    ('%x') or inline-typed ('f32[2,3]{1,0} %x'); commas inside (), [] and
    {} never split."""
    names = []
    depth = 0
    cur = []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            names.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        names.append("".join(cur).strip())
    return [n for n in names if "%" in n]


def _trip_count(comps: Dict[str, Computation], cond_name: str,
                depth: int = 0) -> int:
    """Max integer constant reachable in the loop condition (lax.scan bound)."""
    if depth > 3 or cond_name not in comps:
        return 1
    best = 1
    comp = comps[cond_name]
    for op in comp.ops:
        for c in _CONST_RE.finditer(op.line):
            best = max(best, int(c.group(1)))
        m = _CALLS_RE.search(op.line)
        if m:
            best = max(best, _trip_count(comps, m.group(1), depth + 1))
    return best


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return 1


def _effective_collective_bytes(op: str, b: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return b * (g - 1) / g
    if op == "all-reduce":
        return 2 * b * (g - 1) / g
    if op == "reduce-scatter":
        return b * (g - 1)
    if op == "all-to-all":
        return b * (g - 1) / g
    return b


@dataclass
class Summary:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    loops: List[Tuple[str, int]] = field(default_factory=list)
    collective_f32_effective: float = 0.0   # f32 share (CPU-dot artifact)

    @property
    def collective_effective_bytes(self) -> float:
        return sum(d["effective_bytes"] for d in self.collectives.values())

    @property
    def collective_raw_bytes(self) -> float:
        return sum(d["bytes"] for d in self.collectives.values())

    @property
    def collective_effective_bytes_bf16adj(self) -> float:
        """XLA:CPU lowers bf16 dots to f32, so collectives on dot outputs /
        cotangents parse as f32; on TPU they are bf16. Adjusted = halve the
        f32 share."""
        return (self.collective_effective_bytes
                - self.collective_f32_effective / 2.0)


def _analyze_comp(comps: Dict[str, Computation], name: str, mult: float,
                  s: Summary, seen_depth: int = 0):
    if name not in comps or seen_depth > 32:
        return
    comp = comps[name]
    for op in comp.ops:
        kind = op.op
        if kind == "while":
            m = _COND_BODY_RE.search(op.line)
            if m:
                trips = _trip_count(comps, m.group(1))
                s.loops.append((op.name, trips))
                _analyze_comp(comps, m.group(2), mult * trips, s,
                              seen_depth + 1)
            continue
        if kind in ("fusion", "call", "async-start", "custom-call"):
            m = _CALLS_RE.search(op.line)
            if m:
                _analyze_comp(comps, m.group(1), mult, s, seen_depth + 1)
            continue
        if kind == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-_]+))",
                                 op.line):
                names = (m.group(1) or m.group(2) or "").replace("%", "")
                for n in names.split(","):
                    if n.strip():
                        _analyze_comp(comps, n.strip(), mult, s,
                                      seen_depth + 1)
            continue
        if kind in ("dot", "convolution"):
            outs = _array_dims(op.type)
            out_elems = 0
            for _, dims in outs:
                n = 1
                for d in dims:
                    n *= d
                out_elems += n
            k = 1
            mcd = _LHS_CDIMS_RE.search(op.line)
            ops_list = _operands(op.line.split("(", 1)[1])
            if mcd and ops_list:
                lhs_t = _resolve_shape(comp, ops_list[0])
                if lhs_t:
                    arrs = _array_dims(lhs_t)
                    if arrs:
                        dims = arrs[0][1]
                        for idx in mcd.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                k *= dims[int(idx)]
            s.dot_flops += mult * 2.0 * out_elems * k
            b = _type_bytes(op.type)
            for o in ops_list[:2]:
                t = _resolve_shape(comp, o)
                if t:
                    b += _type_bytes(t)
            s.dot_bytes += mult * b
            continue
        base = kind.replace("-start", "")
        if base in COLLECTIVE_OPS and not kind.endswith("-done"):
            b = _type_bytes(op.type)
            g = _group_size(op.line)
            d = s.collectives.setdefault(base, {"count": 0.0, "bytes": 0.0,
                                                "effective_bytes": 0.0})
            eff = mult * _effective_collective_bytes(base, float(b), g)
            d["count"] += mult
            d["bytes"] += mult * b
            d["effective_bytes"] += eff
            # f32 share of the payload (per-component within tuples)
            total_b = max(b, 1)
            f32_b = sum(
                int(DTYPE_BYTES[dt] * _prod(dims))
                for dt, dims in _array_dims(op.type) if dt == "f32")
            s.collective_f32_effective += eff * f32_b / total_b


def analyze_hlo(text: str) -> Summary:
    comps, entry = parse_module(text)
    s = Summary()
    if entry:
        _analyze_comp(comps, entry, 1.0, s)
    return s
