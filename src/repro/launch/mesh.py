"""Mesh factories. Functions, not module-level constants, so importing this
module never touches jax device state."""
from __future__ import annotations

import numpy as np

import jax


def use_mesh(mesh):
    """Ambient-mesh context manager across JAX versions.

    ``jax.set_mesh`` landed well after 0.4.x; on older releases the Mesh
    object itself is the context manager that installs the ambient mesh
    (needed for bare-PartitionSpec sharding constraints in act.py).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; 2 pods = 512.

    Axes: (data, model) single pod; (pod, data, model) multi-pod. The dry-run
    forces 512 host platform devices; single-pod uses the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_batch_mesh(n_devices=None):
    """1-D mesh over local devices with a single "batch" axis.

    The sim engine shards its (scenario x seed) rollout batch over this
    axis (`engine.rollout_batch_sharded`): rollouts are embarrassingly
    parallel, so a flat device line is the right topology. With one device
    (CPU tests) this degenerates to a 1-mesh — same code path, no-op
    sharding.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if n < 1 or n > len(devices):
        raise ValueError(f"need 1..{len(devices)} devices, asked for {n}")
    return jax.make_mesh((n,), ("batch",), devices=devices[:n])


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """shard_map across JAX versions (`jax.shard_map` landed after 0.4.x;
    older releases ship it under jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs)


def make_local_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"),
                         devices=jax.devices()[:dp * model_parallel])
