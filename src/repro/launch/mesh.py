"""Mesh factories. Functions, not module-level constants, so importing this
module never touches jax device state."""
from __future__ import annotations

import numpy as np

import jax


def use_mesh(mesh):
    """Ambient-mesh context manager across JAX versions.

    ``jax.set_mesh`` landed well after 0.4.x; on older releases the Mesh
    object itself is the context manager that installs the ambient mesh
    (needed for bare-PartitionSpec sharding constraints in act.py).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; 2 pods = 512.

    Axes: (data, model) single pod; (pod, data, model) multi-pod. The dry-run
    forces 512 host platform devices; single-pod uses the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_local_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"),
                         devices=jax.devices()[:dp * model_parallel])
