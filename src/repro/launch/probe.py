import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Dry-run profiler: rank dot ops (flops x trip-count) and collectives with
their JAX-level op_name metadata, to localize sharding/compute waste.

    PYTHONPATH=src python -m repro.launch.probe --arch qwen3-0.6b \
        --shape train_4k --mesh pod --top 25
"""

import argparse
import re

from repro.launch import hlo_analysis as H

_META_RE = re.compile(r'op_name="([^"]*)"')


def top_ops(text: str, top: int = 25):
    comps, entry = H.parse_module(text)
    rows = []
    colls = []

    def walk(name, mult, depth=0):
        if name not in comps or depth > 32:
            return
        for op in comps[name].ops:
            kind = op.op
            if kind == "while":
                m = H._COND_BODY_RE.search(op.line)
                if m:
                    walk(m.group(2), mult * H._trip_count(comps, m.group(1)),
                         depth + 1)
                continue
            if kind in ("fusion", "call", "custom-call", "async-start"):
                m = H._CALLS_RE.search(op.line)
                if m:
                    walk(m.group(1), mult, depth + 1)
                continue
            meta = _META_RE.search(op.line)
            label = meta.group(1) if meta else op.name
            if kind in ("dot", "convolution"):
                outs = H._array_dims(op.type)
                out_elems = sum(int(__import__("numpy").prod(d or [1]))
                                for _, d in outs)
                k = 1
                mcd = H._LHS_CDIMS_RE.search(op.line)
                ops_list = H._operands(op.line.split("(", 1)[1])
                if mcd and ops_list:
                    t = H._resolve_shape(comps[name], ops_list[0])
                    if t:
                        arrs = H._array_dims(t)
                        if arrs:
                            dims = arrs[0][1]
                            for idx in mcd.group(1).split(","):
                                if idx and int(idx) < len(dims):
                                    k *= dims[int(idx)]
                rows.append((mult * 2.0 * out_elems * k, mult, op.type[:48],
                             label))
            base = kind.replace("-start", "")
            if base in H.COLLECTIVE_OPS and not kind.endswith("-done"):
                b = H._type_bytes(op.type)
                g = H._group_size(op.line)
                colls.append((mult * H._effective_collective_bytes(
                    base, float(b), g), mult, base, op.type[:40], label))

    walk(entry, 1.0)
    rows.sort(key=lambda r: -r[0])
    colls.sort(key=lambda r: -r[0])
    return rows[:top], colls[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    from repro.launch.dryrun import lower_cell
    compiled, lowered, meta = lower_cell(args.arch, args.shape,
                                         args.mesh == "multipod")
    text = compiled.as_text()
    dots, colls = top_ops(text, args.top)
    print("== top dots (per-device flops x trips) ==")
    for fl, mult, t, label in dots:
        print(f"  {fl:12.3e}  x{int(mult):4d}  {t:48s}  {label[:110]}")
    print("== top collectives (effective bytes) ==")
    for b, mult, kind, t, label in colls:
        print(f"  {b:12.3e}  x{int(mult):4d}  {kind:18s} {t:40s}  "
              f"{label[:100]}")
    ma = compiled.memory_analysis()
    print(f"mem: args={ma.argument_size_in_bytes/1e9:.2f}GB "
          f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
          f"out={ma.output_size_in_bytes/1e9:.2f}GB "
          f"alias={ma.alias_size_in_bytes/1e9:.2f}GB")


if __name__ == "__main__":
    main()
