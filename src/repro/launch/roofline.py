"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), seconds per step on TPU v5e:

    compute    = per_device_HLO_FLOPs / peak_FLOPs_per_chip
    memory     = per_device_HLO_bytes / HBM_bandwidth
    collective = per_device_effective_collective_bytes / ICI_link_bandwidth

``cost_analysis()`` provides per-device FLOPs/bytes (the compiled module is
the per-device SPMD program). Collective bytes are parsed from the compiled
HLO text; effective per-device bytes use ring formulas:

    all-gather:          out_bytes * (g-1)/g
    all-reduce:          2 * bytes * (g-1)/g
    reduce-scatter:      out_bytes * (g-1)         (out is the shard)
    all-to-all:          bytes * (g-1)/g
    collective-permute:  bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List

# ----------------------------------------------------------------- hardware

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one link per collective hop)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return 1


@dataclass
class Collective:
    op: str
    out_bytes: int
    group: int

    @property
    def effective_bytes(self) -> float:
        g = max(self.group, 1)
        b = float(self.out_bytes)
        if g == 1:
            return 0.0
        if self.op == "all-gather":
            return b * (g - 1) / g
        if self.op == "all-reduce":
            return 2 * b * (g - 1) / g
        if self.op == "reduce-scatter":
            return b * (g - 1)
        if self.op == "all-to-all":
            return b * (g - 1) / g
        return b                       # collective-permute


def parse_collectives(hlo_text: str) -> List[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out.append(Collective(op=m.group("op"),
                              out_bytes=_shape_bytes(m.group("shape")),
                              group=_group_size(line)))
    return out


def collective_summary(colls: List[Collective]) -> Dict:
    by_op: Dict[str, Dict[str, float]] = {}
    for c in colls:
        d = by_op.setdefault(c.op, {"count": 0, "bytes": 0.0,
                                    "effective_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += c.out_bytes
        d["effective_bytes"] += c.effective_bytes
    total = sum(d["effective_bytes"] for d in by_op.values())
    return {"by_op": by_op, "effective_bytes": total}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> Dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        # fraction of peak FLOP/s achieved if the dominant term is the wall
        "roofline_fraction": compute / bound if bound > 0 else 0.0,
    }


# ---------------------------------------------------------- model FLOPs/6ND

def tree_param_count(shapes_tree) -> int:
    import jax
    return sum(int(_np_prod(x.shape)) for x in jax.tree.leaves(shapes_tree))


def _np_prod(t):
    n = 1
    for x in t:
        n *= int(x)
    return n


def active_param_count(cfg, param_shapes) -> int:
    """Total params minus the share of routed experts beyond top_k."""
    import jax
    total = tree_param_count(param_shapes)
    if cfg.moe is None:
        return total
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes)[0]:
        names = [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]
        if "ffn" in names and names[-1] in ("wi", "wo") \
                and "shared" not in names and "prefix_0" not in names:
            routed += int(_np_prod(leaf.shape))
    inactive = routed * (1.0 - cfg.moe.top_k / cfg.moe.num_experts)
    return int(total - inactive)


def model_flops(cfg, shape, param_shapes) -> float:
    """6*N_active*D for train; 2*N_active*D forward-only (prefill/decode)."""
    n_active = active_param_count(cfg, param_shapes)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch           # one new token per sequence
    return 2.0 * n_active * tokens
