"""Batched serving driver: prefill + decode with a KV cache, with optional
VCC-gated admission of new request batches (carbon-aware serving of
*flexible* batch inference; latency-critical serving is never gated —
paper: inflexible workloads are untouched).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.train import CarbonGate
from repro.models import build_model
from repro.training import make_prefill_step, make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--carbon-aware", action="store_true")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = (arch.smoke if args.smoke else arch.config).replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen + 8
    prefill = jax.jit(make_prefill_step(model, max_seq))
    decode = jax.jit(make_serve_step(model), donate_argnums=(1,))
    gate = CarbonGate() if args.carbon_aware else None
    rng = np.random.RandomState(0)
    total_tokens = 0
    t0 = time.time()
    for r in range(args.rounds):
        if gate is not None:
            cap = gate.capacity[r % 24]
            bsz = max(1, int(round(args.batch * min(cap, 1.5))))
            print(f"[serve] round {r}: hour={r % 24} carbon="
                  f"{gate.intensity[r % 24]:.3f} admitted batch={bsz}")
        else:
            bsz = args.batch
        toks = rng.randint(1, cfg.vocab_size,
                           size=(bsz, args.prompt_len)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (bsz, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (bsz, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos0 = args.prompt_len + (cfg.vision_tokens
                                  if cfg.family == "vlm" else 0)
        out = [tok]
        for i in range(args.gen):
            logits, cache = decode(params, cache, tok,
                                   jnp.asarray(pos0 + i, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        total_tokens += bsz * (args.gen + 1)
        sample = np.stack([np.asarray(t) for t in out], 1)[0][:12]
        print(f"[serve] round {r}: generated {args.gen} toks/seq; "
              f"sample: {sample.tolist()}")
    dt = time.time() - t0
    print(f"[serve] {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
