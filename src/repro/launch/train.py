"""Trainer: checkpoint/restart, deterministic batch replay, optional
carbon-aware (VCC-gated) step pacing, optional int8 gradient compression.

The trainer is the fleet's canonical *flexible workload*: when launched with
``--carbon-aware`` it consults a VCC-derived hourly capacity gate and shifts
its step budget toward green hours — the workload-side view of the paper's
mechanism (cluster-side shaping lives in repro.core).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 200 --ckpt-dir /tmp/ck --carbon-aware

Fault tolerance: kill it at any point; relaunching with the same flags
resumes from the last committed checkpoint and replays the exact batch
stream (see repro.data). Elastic: checkpoints restore onto a different
device count.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_arch
from repro.core import carbon as carbon_mod
from repro.data import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.optim.compression import init_error_feedback, roundtrip
from repro.sharding.act import activation_sharding
from repro.training import make_train_step


class CarbonGate:
    """Hourly step-budget gate derived from a (simulated) VCC curve."""

    def __init__(self, seed: int = 0):
        zone = carbon_mod.default_zones(1)[0]
        intensity = carbon_mod.simulate_zone(jax.random.PRNGKey(seed), zone,
                                             1)[0]
        # flexible capacity fraction: inverse-rank of carbon intensity,
        # conserving the daily budget (mean == 1.0) — a 1-cluster VCC.
        inv = 1.0 / np.clip(np.asarray(intensity), 1e-3, None)
        self.capacity = inv / inv.mean()
        self.intensity = np.asarray(intensity)

    def steps_for_hour(self, hour: int, base: int) -> int:
        return max(0, int(round(base * self.capacity[hour % 24])))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--carbon-aware", action="store_true")
    ap.add_argument("--steps-per-hour", type=int, default=20)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--kill-at-step", type=int, default=-1,
                    help="fault injection: hard-exit at this step")
    ap.add_argument("--step-deadline-s", type=float, default=0.0,
                    help="straggler mitigation: steps exceeding this wall "
                         "time are logged as straggler events (a real pod "
                         "runner would preempt/replace the slow host; the "
                         "deterministic pipeline makes replay safe)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = (arch.smoke if args.smoke else arch.config).replace(remat="none")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                          decay_steps=max(args.steps, 100))
    mesh = make_local_mesh()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt_cfg)
    ef = init_error_feedback(params) if args.compress else None

    base_step_fn = make_train_step(model, opt_cfg)
    if args.compress:
        from repro.optim import adamw_update

        def step_fn(params, opt_state, batch, ef):
            def loss_fn(p):
                return model.loss(p, batch)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            grads, ef = roundtrip(grads, ef)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics, ef

        jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 3))
    else:
        jit_step = jax.jit(base_step_fn, donate_argnums=(0, 1))

    start = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            tree = {"params": params, "opt": opt_state}
            restored = ckpt.restore(args.ckpt_dir, last,
                                    jax.eval_shape(lambda: tree))
            params, opt_state = restored["params"], restored["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    gate = CarbonGate() if args.carbon_aware else None
    step = start
    hour = start // max(args.steps_per_hour, 1)
    t0 = time.time()
    losses = []
    with activation_sharding(mesh):
        while step < args.steps:
            if gate is not None:
                budget = gate.steps_for_hour(hour, args.steps_per_hour)
            else:
                budget = args.steps_per_hour
            for _ in range(budget):
                if step >= args.steps:
                    break
                batch = {k: jnp.asarray(v)
                         for k, v in batch_at(dcfg, step).items()}
                if cfg.family == "vlm":
                    batch["vision_embeds"] = jnp.zeros(
                        (args.batch, cfg.vision_tokens, cfg.d_model),
                        jnp.dtype(cfg.dtype))
                if cfg.family == "encdec":
                    batch["frames"] = jnp.zeros(
                        (args.batch, cfg.encoder_seq, cfg.d_model),
                        jnp.dtype(cfg.dtype))
                ts = time.time()
                if args.compress:
                    params, opt_state, metrics, ef = jit_step(
                        params, opt_state, batch, ef)
                else:
                    params, opt_state, metrics = jit_step(params, opt_state,
                                                          batch)
                if args.step_deadline_s and step > start + 1 \
                        and time.time() - ts > args.step_deadline_s:
                    print(f"[train] STRAGGLER step={step + 1} took "
                          f"{time.time() - ts:.2f}s "
                          f"(deadline {args.step_deadline_s}s)")
                step += 1
                if step == args.kill_at_step:
                    print(f"[train] fault injection: dying at step {step}")
                    import os
                    os._exit(42)
                if step % args.log_every == 0:
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    rate = (step - start) / (time.time() - t0)
                    extra = (f" hour={hour % 24:02d} budget={budget}"
                             if gate else "")
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"steps/s={rate:.2f}{extra}")
                if args.ckpt_dir and step % args.ckpt_every == 0:
                    ckpt.save(args.ckpt_dir, step,
                              {"params": params, "opt": opt_state},
                              async_=False)
            hour += 1
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, step, {"params": params, "opt": opt_state})
    print(f"[train] done at step {step}; final loss "
          f"{losses[-1] if losses else float('nan'):.4f}")
    return losses


if __name__ == "__main__":
    main()
