from repro.models.model import (build_model, param_specs, cache_specs,
                                batch_specs, decode_specs, input_specs)

__all__ = ["build_model", "param_specs", "cache_specs", "batch_specs",
           "decode_specs", "input_specs"]
