"""Attention mixers: GQA (qk-norm / local-global / softcap) and MLA.

Full-sequence paths route through ``repro.kernels.flash_attention.ops`` (Pallas
on TPU, bounded-memory XLA elsewhere). Decode paths operate on a KV cache via
``jax.lax.dynamic_update_slice``; MLA decode uses the matrix-absorption trick
on the compressed latent cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as attn_ops
from repro.models import layers as L

GLOBAL_WINDOW = 1 << 30  # "no window" sentinel large enough for any seq


# ------------------------------------------------------------------ GQA

def init_gqa(key, cfg: ModelConfig, dtype):
    a = cfg.attn
    D, N, K, H = cfg.d_model, a.num_heads, a.num_kv_heads, a.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (D, N, H), (0,), dtype),
        "wk": L.dense_init(ks[1], (D, K, H), (0,), dtype),
        "wv": L.dense_init(ks[2], (D, K, H), (0,), dtype),
        "wo": L.dense_init(ks[3], (N, H, D), (0, 1), dtype),
    }
    if a.qk_norm:
        p["q_norm"] = L.init_rms(H)
        p["k_norm"] = L.init_rms(H)
    return p


def _project_qkv(p, cfg, x, positions):
    a = cfg.attn
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if a.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, a.rope_theta)
    k = L.rope(k, positions, a.rope_theta)
    return q, k, v


def apply_gqa(p, cfg: ModelConfig, x, positions, *, causal=True, window=None,
              return_kv: bool = False):
    """Full-sequence GQA. x: (B, S, D). window: None | int | traced scalar
    (per-layer local/global selection inside a scan)."""
    a = cfg.attn
    q, k, v = _project_qkv(p, cfg, x, positions)
    o = attn_ops.attention(q, k, v, causal=causal, window=window,
                           softcap=a.attn_softcap)
    out = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def apply_gqa_decode(p, cfg: ModelConfig, x, kc, vc, pos, *, window=None):
    """One decode step. x: (B, 1, D); kc/vc: (B, Smax, K, H); pos: scalar.
    Returns (out (B,1,D), new kc, new vc)."""
    a = cfg.attn
    q, k, v = _project_qkv(p, cfg, x, pos[None] if jnp.ndim(pos) == 0
                           else pos)
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    o = attn_ops.attention(q, kc, vc, causal=True, window=window,
                           softcap=a.attn_softcap, q_offset=pos,
                           length=pos + 1)
    out = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    return out, kc, vc


# ------------------------------------------------------------------ MLA

def init_mla(key, cfg: ModelConfig, dtype):
    m, a = cfg.mla, cfg.attn
    D, N = cfg.d_model, a.num_heads
    qh = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.dense_init(ks[0], (D, m.q_lora_rank), (0,), dtype),
        "q_norm": L.init_rms(m.q_lora_rank),
        "wq_b": L.dense_init(ks[1], (m.q_lora_rank, N, qh), (0,), dtype),
        "wkv_a": L.dense_init(ks[2], (D, m.kv_lora_rank + m.rope_head_dim),
                              (0,), dtype),
        "kv_norm": L.init_rms(m.kv_lora_rank),
        "wk_b": L.dense_init(ks[3], (m.kv_lora_rank, N, m.nope_head_dim),
                             (0,), dtype),
        "wv_b": L.dense_init(ks[4], (m.kv_lora_rank, N, m.v_head_dim),
                             (0,), dtype),
        "wo": L.dense_init(ks[5], (N, m.v_head_dim, D), (0, 1), dtype),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    cq = L.rms_norm(jnp.einsum("bsd,dl->bsl", x, p["wq_a"]), p["q_norm"],
                    cfg.norm_eps)
    q = jnp.einsum("bsl,lnh->bsnh", cq, p["wq_b"])
    q_nope = q[..., :m.nope_head_dim]
    q_rope = L.rope(q[..., m.nope_head_dim:], positions, cfg.attn.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, cfg, x, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    ckv = L.rms_norm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., None, m.kv_lora_rank:]           # (B, S, 1, rope_hd)
    k_rope = L.rope(k_rope, positions, cfg.attn.rope_theta)[:, :, 0]
    return ckv, k_rope


def apply_mla(p, cfg: ModelConfig, x, positions, *, return_kv: bool = False):
    """Full-sequence MLA (expanded path). x: (B, S, D)."""
    m, a = cfg.mla, cfg.attn
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, k_rope = _mla_kv_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsl,lnh->bsnh", ckv, p["wk_b"])
    v = jnp.einsum("bsl,lnh->bsnh", ckv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  k_nope.shape[:3] + (m.rope_head_dim,))], -1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    # pad v to q/k head_dim for the shared attention op, then slice back
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                     (0, q.shape[-1] - v.shape[-1])))
    o = attn_ops.attention(q, k, vp, causal=True, scale=scale)
    o = o[..., :m.v_head_dim]
    out = jnp.einsum("bsnv,nvd->bsd", o, p["wo"])
    if return_kv:
        return out, (ckv, k_rope)
    return out


def apply_mla_decode(p, cfg: ModelConfig, x, ckv_c, krope_c, pos):
    """Matrix-absorbed MLA decode. x: (B, 1, D); ckv_c: (B, Smax, kv_lora);
    krope_c: (B, Smax, rope_hd). Returns (out, new ckv_c, new krope_c)."""
    m = cfg.mla
    posv = pos[None] if jnp.ndim(pos) == 0 else pos
    q_nope, q_rope = _mla_q(p, cfg, x, posv)           # (B,1,N,·)
    ckv, k_rope = _mla_kv_latent(p, cfg, x, posv)      # (B,1,lora),(B,1,rope)
    # absorb W_UK into q: (B,1,N,lora)
    q_eff = jnp.einsum("bqnh,lnh->bqnl", q_nope, p["wk_b"])
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    from repro.sharding.act import current_mesh
    mesh = current_mesh()
    if cfg.flash_decode and mesh is not None:
        ctx, ckv_c, krope_c = _mla_flash_decode(
            mesh, q_eff, q_rope, ckv, k_rope, ckv_c, krope_c, pos, scale)
    else:
        ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv.astype(ckv_c.dtype),
                                             (0, pos, 0))
        krope_c = jax.lax.dynamic_update_slice(
            krope_c, k_rope.astype(krope_c.dtype), (0, pos, 0))
        s = (jnp.einsum("bqnl,bsl->bnqs", q_eff.astype(jnp.float32),
                        ckv_c.astype(jnp.float32))
             + jnp.einsum("bqnr,bsr->bnqs", q_rope.astype(jnp.float32),
                          krope_c.astype(jnp.float32)))
        s = s * scale
        mask = (jnp.arange(ckv_c.shape[1]) <= pos)[None, None, None]
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bnqs,bsl->bqnl", w, ckv_c.astype(jnp.float32))
    o = jnp.einsum("bqnl,lnv->bqnv", ctx.astype(x.dtype), p["wv_b"])
    out = jnp.einsum("bqnv,nvd->bqd", o, p["wo"])
    return out, ckv_c, krope_c


def _mla_flash_decode(mesh, q_eff, q_rope, ckv_new, krope_new, ckv_c,
                      krope_c, pos, scale):
    """Flash-decode over a sequence-sharded MLA latent cache (shard_map
    across the `model` axis). Each shard computes partial softmax stats on
    its S/tp slice; combination psums only (B, N) stats and the (B, N, R)
    context — collectives shrink from full-score psums to per-head stats.

    Sharding: ckv_c/krope_c are P(batch, 'model', None); q/new-kv entries
    replicated across 'model'.
    """
    from jax.sharding import PartitionSpec as P
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    S_local = ckv_c.shape[1] // mesh.shape["model"]

    def shard_fn(q_eff, q_rope, ckv_new, krope_new, ckv_c, krope_c, pos):
        idx = jax.lax.axis_index("model")
        start = idx * S_local
        lpos = pos - start
        in_range = (lpos >= 0) & (lpos < S_local)
        cl = jnp.clip(lpos, 0, S_local - 1)
        cur_ckv = jax.lax.dynamic_slice(
            ckv_c, (0, cl, 0), (ckv_c.shape[0], 1, ckv_c.shape[2]))
        cur_kr = jax.lax.dynamic_slice(
            krope_c, (0, cl, 0), (krope_c.shape[0], 1, krope_c.shape[2]))
        ckv_c = jax.lax.dynamic_update_slice(
            ckv_c, jnp.where(in_range, ckv_new.astype(ckv_c.dtype),
                             cur_ckv), (0, cl, 0))
        krope_c = jax.lax.dynamic_update_slice(
            krope_c, jnp.where(in_range, krope_new.astype(krope_c.dtype),
                               cur_kr), (0, cl, 0))
        s = (jnp.einsum("bqnl,bsl->bnqs", q_eff.astype(jnp.float32),
                        ckv_c.astype(jnp.float32))
             + jnp.einsum("bqnr,bsr->bnqs", q_rope.astype(jnp.float32),
                          krope_c.astype(jnp.float32))) * scale
        kpos = start + jnp.arange(S_local)
        s = jnp.where((kpos <= pos)[None, None, None], s, -1e30)
        mx = s.max(axis=-1)                          # (B,N,1)
        w = jnp.exp(s - mx[..., None])
        l = w.sum(axis=-1)                           # (B,N,1)
        ctx = jnp.einsum("bnqs,bsl->bqnl", w, ckv_c.astype(jnp.float32))
        # combine across shards: logsumexp-weighted psums of small stats
        gmx = jax.lax.pmax(mx, "model")
        corr = jnp.exp(mx - gmx)
        gl = jax.lax.psum(l * corr, "model")
        gctx = jax.lax.psum(ctx * corr.transpose(0, 2, 1)[..., None],
                            "model")
        ctx = gctx / jnp.maximum(gl, 1e-30).transpose(0, 2, 1)[..., None]
        return ctx, ckv_c, krope_c

    in_specs = (P(ba, None, None, None), P(ba, None, None, None),
                P(ba, None, None), P(ba, None, None),
                P(ba, "model", None), P(ba, "model", None), P())
    out_specs = (P(ba, None, None, None), P(ba, "model", None),
                 P(ba, "model", None))
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    else:  # jax<=0.4: experimental API, replication check flag spelled
        from jax.experimental.shard_map import shard_map
        mapped = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
    return mapped(q_eff, q_rope, ckv_new, krope_new, ckv_c, krope_c, pos)
