"""Whisper-style encoder-decoder (conv/mel frontend is a stub: the encoder
consumes precomputed frame embeddings from ``input_specs()``).

Pre-LN LayerNorm blocks, GELU MLPs, sinusoidal absolute positions (decoder
positions sinusoidal instead of Whisper's 448 learned ones — DESIGN.md §4).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as attn_ops
from repro.models import layers as L
from repro.models.transformer import _dtype, _remat, _stack_init, _pad_kv_to
from repro.sharding.act import constrain

f32 = jnp.float32


def _ln(x, p, eps):
    return L.layer_norm(x, p["scale"], p["bias"], eps)


def _init_attn(key, cfg, dtype):
    a = cfg.attn
    D, N, H = cfg.d_model, a.num_heads, a.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": L.dense_init(ks[0], (D, N, H), (0,), dtype),
            "wk": L.dense_init(ks[1], (D, N, H), (0,), dtype),
            "wv": L.dense_init(ks[2], (D, N, H), (0,), dtype),
            "wo": L.dense_init(ks[3], (N, H, D), (0, 1), dtype)}


def _attn(p, x_q, x_kv, *, causal, q_offset=0, length=None, kv=None):
    """Self- or cross-attention. kv: optional precomputed (k, v)."""
    q = jnp.einsum("bsd,dnh->bsnh", x_q, p["wq"])
    if kv is None:
        k = jnp.einsum("bsd,dnh->bsnh", x_kv, p["wk"])
        v = jnp.einsum("bsd,dnh->bsnh", x_kv, p["wv"])
    else:
        k, v = kv
    o = attn_ops.attention(q, k, v, causal=causal, q_offset=q_offset,
                           length=length)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"]), (k, v)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    # ---------------- params
    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 3)

        def init_enc(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": L.init_ln(cfg.d_model),
                    "attn": _init_attn(k1, cfg, dt),
                    "ln2": L.init_ln(cfg.d_model),
                    "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act,
                                      dt)}

        def init_dec(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": L.init_ln(cfg.d_model),
                    "self": _init_attn(k1, cfg, dt),
                    "ln2": L.init_ln(cfg.d_model),
                    "cross": _init_attn(k2, cfg, dt),
                    "ln3": L.init_ln(cfg.d_model),
                    "ffn": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act,
                                      dt)}

        return {
            "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "enc_stack": _stack_init(init_enc, keys[1], cfg.encoder_layers),
            "enc_norm": L.init_ln(cfg.d_model),
            "dec_stack": _stack_init(init_dec, keys[2], cfg.num_layers),
            "dec_norm": L.init_ln(cfg.d_model),
        }

    # ---------------- encoder
    def encode(self, p, frames):
        cfg = self.cfg
        S = frames.shape[1]
        pos = L.sinusoidal_positions(jnp.arange(S), cfg.d_model)
        x = frames + pos[None].astype(frames.dtype)

        def body(x, lp):
            x = constrain(x, "batch", None, None)
            h = _ln(x, lp["ln1"], cfg.norm_eps)
            a, _ = _attn(lp["attn"], h, h, causal=False)
            x = x + a
            h = _ln(x, lp["ln2"], cfg.norm_eps)
            return x + L.apply_mlp(lp["ffn"], h, cfg.act), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, p["enc_stack"])
        return _ln(x, p["enc_norm"], cfg.norm_eps)

    # ---------------- decoder
    def decode_full(self, p, tokens, enc_out, *, collect_kv=False):
        cfg = self.cfg
        S = tokens.shape[1]
        pos = L.sinusoidal_positions(jnp.arange(S), cfg.d_model)
        x = p["embed"][tokens] + pos[None].astype(_dtype(cfg))

        def body(x, lp):
            x = constrain(x, "batch", None, None)
            h = _ln(x, lp["ln1"], cfg.norm_eps)
            a, skv = _attn(lp["self"], h, h, causal=True)
            x = x + a
            h = _ln(x, lp["ln2"], cfg.norm_eps)
            a, ckv = _attn(lp["cross"], h, enc_out, causal=False)
            x = x + a
            h = _ln(x, lp["ln3"], cfg.norm_eps)
            x = x + L.apply_mlp(lp["ffn"], h, cfg.act)
            return x, (skv, ckv) if collect_kv else None

        x, kvs = jax.lax.scan(_remat(body, cfg), x, p["dec_stack"])
        x = _ln(x, p["dec_norm"], cfg.norm_eps)
        return (x, kvs) if collect_kv else x

    # ---------------- training
    def loss(self, p, batch):
        tokens = batch["tokens"]
        enc_out = self.encode(p, batch["frames"])
        x = self.decode_full(p, tokens[:, :-1], enc_out)
        return L.chunked_xent(x, p["embed"], tokens[:, 1:])

    # ---------------- serving
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        a = cfg.attn
        dt = _dtype(cfg)
        Ld, Le = cfg.num_layers, cfg.encoder_seq
        kv = lambda s: jnp.zeros((Ld, batch, s, a.num_heads, a.head_dim), dt)
        return {"self_k": kv(max_seq), "self_v": kv(max_seq),
                "cross_k": kv(Le), "cross_v": kv(Le)}

    def prefill(self, p, batch, max_seq: int):
        enc_out = self.encode(p, batch["frames"])
        x, kvs = self.decode_full(p, batch["tokens"], enc_out,
                                  collect_kv=True)
        (sk, sv), (ck, cv) = kvs
        cache = {"self_k": _pad_kv_to(sk, max_seq, axis=2),
                 "self_v": _pad_kv_to(sv, max_seq, axis=2),
                 "cross_k": ck, "cross_v": cv}
        logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(f32),
                            p["embed"].astype(f32))
        return logits, cache

    def decode_step(self, p, cache, token, pos):
        cfg = self.cfg
        posemb = L.sinusoidal_positions(pos[None] if jnp.ndim(pos) == 0
                                        else pos, cfg.d_model)
        x = p["embed"][token[:, None]] + posemb[None].astype(_dtype(cfg))

        def body(x, inp):
            lp, sk, sv, ck, cv = inp
            h = _ln(x, lp["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dnh->bsnh", h, lp["self"]["wq"])
            k = jnp.einsum("bsd,dnh->bsnh", h, lp["self"]["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, lp["self"]["wv"])
            sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype),
                                              (0, pos, 0, 0))
            sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype),
                                              (0, pos, 0, 0))
            o = attn_ops.attention(q, sk, sv, causal=True, q_offset=pos,
                                   length=pos + 1)
            x = x + jnp.einsum("bsnh,nhd->bsd", o, lp["self"]["wo"])
            h = _ln(x, lp["ln2"], cfg.norm_eps)
            a, _ = _attn(lp["cross"], h, None, causal=False, kv=(ck, cv))
            x = x + a
            h = _ln(x, lp["ln3"], cfg.norm_eps)
            x = x + L.apply_mlp(lp["ffn"], h, cfg.act)
            return x, (sk, sv)

        x, (nsk, nsv) = jax.lax.scan(
            body, x, (p["dec_stack"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
        x = _ln(x, p["dec_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(f32),
                            p["embed"].astype(f32))
        return logits, {"self_k": nsk, "self_v": nsv,
                        "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"]}
