"""Shared model building blocks: norms, RoPE, MLPs, embeddings, chunked loss."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.act import constrain


# ---------------------------------------------------------------- init utils

def dense_init(key, shape, in_axes=(0,), dtype=jnp.bfloat16, scale=1.0):
    """Truncated-normal init with stddev scale/sqrt(fan_in)."""
    fan_in = 1
    for a in in_axes:
        fan_in *= shape[a]
    std = scale * (fan_in ** -0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (d ** -0.5)).astype(dtype)


# --------------------------------------------------------------------- norms

def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def group_norm_heads(x, scale, bias, eps=1e-5):
    """Per-head layernorm (RWKV 'ln_x'). x: (..., H, hd); scale/bias: (H, hd)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_rms(d, dtype=jnp.float32):
    return jnp.zeros((d,), dtype)          # rms_norm uses (1 + scale)


def init_ln(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """Rotary embedding, llama rotate-half convention.
    x: (B, S, N, H); positions: (S,) or (B, S)."""
    if theta == 0.0:
        return x
    B, S, N, H = x.shape
    half = H // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[:, :, None] * freqs[None, None, :]        # (B|1, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d, base: float = 10_000.0):
    """Whisper-style sinusoidal embeddings. positions: (S,) -> (S, d)."""
    half = d // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ----------------------------------------------------------------------- mlp

def init_mlp(key, d_model, d_ff, act: str, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    gated = act in ("swiglu", "geglu")
    wi = dense_init(k1, (d_model, (2 if gated else 1) * d_ff), (0,), dtype)
    wo = dense_init(k2, (d_ff, d_model), (0,), dtype)
    return {"wi": wi, "wo": wo}


def apply_mlp(p, x, act: str):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if act in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# -------------------------------------------------------- chunked vocab loss

def chunked_xent(hidden, head, labels, *, mask=None,
                 logit_softcap: Optional[float] = None,
                 chunk: int = 512, z_loss: float = 1e-4):
    """Cross-entropy over a large vocab without materializing full logits.

    hidden: (B, S, D); head: (V, D) (unembedding / tied embedding matrix);
    labels: (B, S) int32; mask: (B, S) float/bool or None. Scans over
    S-chunks; each chunk's logits (B, chunk, V) are transient (remat-like
    memory profile). Returns (mean_loss, metrics dict).
    """
    B, S, D = hidden.shape
    pad = (-S) % chunk
    nc = (S + pad) // chunk
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hs = hidden.reshape(B, nc, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(acc, inp):
        h, lab, m = inp
        h = constrain(h, "batch", None, None)
        logits = jnp.einsum("bsd,vd->bsv", h, head,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "batch", None, "model")
        logits = softcap(logits, logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * m
        zl = jnp.square(lse) * m
        correct = (jnp.argmax(logits, -1) == lab) * m
        return (acc[0] + nll.sum(), acc[1] + zl.sum(),
                acc[2] + correct.sum(), acc[3] + m.sum()), None

    init = (jnp.zeros((), jnp.float32),) * 4
    (nll, zl, correct, n), _ = jax.lax.scan(
        jax.checkpoint(body), init, (hs, ls, ms))
    n = jnp.maximum(n, 1.0)
    loss = nll / n + z_loss * zl / n
    return loss, {"xent": nll / n, "accuracy": correct / n, "tokens": n}
