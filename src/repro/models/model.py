"""Unified model API + input specs for every (arch x shape) cell.

``build_model(cfg)`` returns a family-appropriate model object exposing
``init / loss / prefill / decode_step / init_cache``.

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for every
model input of the lowered step function — weak-type-correct, shardable, no
device allocation (the dry-run pattern).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM, RWKVLM, ZambaLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "hybrid":
        return ZambaLM(cfg)
    if cfg.family == "ssm":
        return RWKVLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(cfg.family)


def param_specs(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (eval_shape)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, max_seq))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs to loss (train) or prefill. Token counts follow the assigned
    shape: seq_len is the TOTAL sequence (incl. vision/frame stubs)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    extra = 1 if shape.kind == "train" else 0
    if cfg.family == "vlm":
        s_text = S - cfg.vision_tokens
        return {"tokens": _sds((B, s_text + extra), jnp.int32),
                "vision_embeds": _sds((B, cfg.vision_tokens, cfg.d_model),
                                      dt)}
    if cfg.family == "encdec":
        return {"tokens": _sds((B, S + extra), jnp.int32),
                "frames": _sds((B, cfg.encoder_seq, cfg.d_model), dt)}
    return {"tokens": _sds((B, S + extra), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, token, pos) specs for one decode step with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = cache_specs(cfg, B, S)
    cache = jax.tree.map(lambda x: _sds(x.shape, x.dtype), cache)
    return cache, _sds((B,), jnp.int32), _sds((), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """All inputs for the step function selected by shape.kind."""
    if shape.kind in ("train", "prefill"):
        return (batch_specs(cfg, shape),)
    return decode_specs(cfg, shape)
