"""Fine-grained Mixture-of-Experts (DeepSeekMoE family).

Shared experts (always-on) + routed experts with top-k gating. Two dispatch
implementations, selectable via ``MoEConfig.dispatch``:

* ``"einsum"`` — GShard-style capacity-factor dispatch with one-hot
  (group, token, expert, slot) combine tensors; the faithful TPU-era baseline.
* ``"scatter"`` — slot-index scatter/gather dispatch, which avoids the
  one-hot einsum FLOPs (beyond-paper optimization; see EXPERIMENTS.md §Perf).

Expert weights carry a leading E axis sharded on the ``model`` mesh axis, so
expert-parallel all-to-alls emerge from the SPMD partitioner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding.act import constrain


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": L.dense_init(ks[0], (D, E), (0,), jnp.float32),
        "wi": L.dense_init(ks[1], (E, D, 2 * F), (1,), dtype),
        "wo": L.dense_init(ks[2], (E, F, D), (1,), dtype),
    }
    if m.num_shared:
        p["shared"] = L.init_mlp(ks[3], D, m.num_shared * F, "swiglu", dtype)
    return p


def _route(m, xg, router):
    """Top-k routing. xg: (G, S, D) -> gate weights and indices (G, S, k)."""
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return probs, topv, topi


def _aux_loss(m, probs, topi):
    """Switch-style load-balancing loss (per group, then averaged)."""
    E = m.num_experts
    me = probs.mean(axis=(0, 1))                              # (E,)
    disp = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    ce = disp.mean(axis=(0, 1))
    return E * jnp.sum(me * ce)


def _positions(m, topi, S, no_drop=False):
    """GShard slot assignment: choice j gets slots after choices < j.
    Returns (pos (G,S,k) slot-in-expert, keep (G,S,k) bool)."""
    E = m.num_experts
    C = _capacity(m, S, no_drop)
    pos_list, keep_list = [], []
    counts = 0
    for j in range(m.top_k):
        mj = jax.nn.one_hot(topi[..., j], E, dtype=jnp.int32)   # (G,S,E)
        cum = jnp.cumsum(mj, axis=1) - mj + counts
        pj = jnp.sum(cum * mj, axis=-1)                          # (G,S)
        keep_list.append(pj < C)
        pos_list.append(pj)
        counts = counts + jnp.sum(mj, axis=1, keepdims=True)     # (G,1,E)
    return jnp.stack(pos_list, -1), jnp.stack(keep_list, -1)


def _capacity(m, S: int, no_drop: bool = False) -> int:
    if no_drop:
        return S        # worst case: every token routes to the same expert
    return max(1, int(S * m.top_k / m.num_experts * m.capacity_factor))


def _experts(p, xe):
    """xe: (G, E, C, D) -> (G, E, C, D) through per-expert SwiGLU."""
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    return jnp.einsum("gecf,efd->gecd", h, p["wo"])


def _dispatch_einsum(p, m, xg, topv, topi, no_drop=False):
    G, S, D = xg.shape
    E, C = m.num_experts, _capacity(m, S, no_drop)
    pos, keep = _positions(m, topi, S, no_drop)
    y = jnp.zeros_like(xg)
    dispatch = jnp.zeros((G, S, E, C), xg.dtype)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    for j in range(m.top_k):
        oh = (jax.nn.one_hot(topi[..., j], E, dtype=xg.dtype)[..., None]
              * jax.nn.one_hot(pos[..., j], C, dtype=xg.dtype)[..., None, :])
        oh = oh * keep[..., j, None, None].astype(xg.dtype)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * topv[..., j, None, None]
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    xe = constrain(xe, "batch", "model", None, None)
    ye = _experts(p, xe)
    ye = constrain(ye, "batch", "model", None, None)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), ye)
    return constrain(y, "batch", None, None)


def _dispatch_scatter(p, m, xg, topv, topi, no_drop=False):
    G, S, D = xg.shape
    E, C = m.num_experts, _capacity(m, S, no_drop)
    pos, keep = _positions(m, topi, S, no_drop)
    slot = topi * C + jnp.minimum(pos, C - 1)                 # (G,S,k)
    w = topv * keep.astype(jnp.float32)

    def one_group(xs, slots, keeps):
        buf = jnp.zeros((E * C, D), xs.dtype)
        for j in range(m.top_k):
            buf = buf.at[slots[:, j]].add(
                xs * keeps[:, j, None].astype(xs.dtype), mode="drop")
        return buf

    xe = jax.vmap(one_group)(xg, slot, keep)                  # (G, E*C, D)
    ye = _experts(p, xe.reshape(G, E, C, D)).reshape(G, E * C, D)

    def gather_group(ys, slots, ws):
        out = 0.0
        for j in range(m.top_k):
            out = out + ys[slots[:, j]] * ws[:, j, None].astype(ys.dtype)
        return out

    return jax.vmap(gather_group)(ye, slot, w)


def apply_moe(p, cfg: ModelConfig, x, *, no_drop: bool = False):
    """x: (B, S, D) -> (y, aux_loss). Routed top-k + shared experts.
    no_drop=True (decode/serving): capacity covers the worst case so no
    token is ever dropped."""
    # NOTE(§Perf B2, refuted): splitting decode tokens into one group per
    # batch shard was hypothesized to preserve batch sharding through the
    # dispatch; measured 8x WORSE collectives (per-group all-to-alls
    # between the data-sharded G axis and model-sharded E axis). Single
    # global group retained for decode.
    m = cfg.moe
    B, S, D = x.shape
    gs = min(m.group_size, B * S)
    if (B * S) % gs != 0:        # odd token counts: one group of everything
        gs = B * S
    G = B * S // gs
    xg = constrain(x.reshape(G, gs, D), "batch", None, None)
    probs, topv, topi = _route(m, xg, p["router"])
    if m.dispatch == "scatter":
        y = _dispatch_scatter(p, m, xg, topv, topi, no_drop)
    else:
        y = _dispatch_einsum(p, m, xg, topv, topi, no_drop)
    y = y.reshape(B, S, D)
    if m.num_shared:
        y = y + L.apply_mlp(p["shared"], x, "swiglu")
    return y, m.router_aux_weight * _aux_loss(m, probs, topi)
