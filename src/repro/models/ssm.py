"""Recurrent mixers: Mamba2 (zamba2) and RWKV6 "Finch" (rwkv6).

Both reduce to the chunked gated-linear-attention primitive in
``repro.kernels.linear_scan`` (Pallas on TPU, chunked XLA elsewhere):

* Mamba2: scalar per-head decay ``exp(-dt * exp(A_log))``; dt folded into v.
* RWKV6: per-channel data-dependent decay ``exp(-exp(w0 + lora(x)))`` with
  the "bonus" u term and strict (h_{t-1}) causality.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.linear_scan import ops as gla_ops
from repro.models import layers as L
from repro.sharding.act import constrain


# ------------------------------------------------------------------- Mamba2

def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    E = s.expand * cfg.d_model
    H = E // s.head_dim
    conv_dim = E + 2 * s.state_dim
    return E, H, conv_dim


def init_mamba(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    D = cfg.d_model
    E, H, conv_dim = mamba_dims(cfg)
    ks = jax.random.split(key, 3)
    dt = jnp.exp(jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), H))
    return {
        "w_in": L.dense_init(ks[0], (D, 2 * E + 2 * s.state_dim + H), (0,),
                             dtype),
        "conv_w": L.dense_init(ks[1], (s.conv_width, conv_dim), (0,), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm": L.init_rms(E),
        "w_out": L.dense_init(ks[2], (E, D), (0,), dtype),
    }


def _mamba_proj(p, cfg, x):
    s = cfg.ssm
    E, H, _ = mamba_dims(cfg)
    N = s.state_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xin, Bc, Cc, dt = jnp.split(zxbcdt, [E, 2 * E, 2 * E + N,
                                            2 * E + 2 * N], axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_conv(p, cfg, conv_in, conv_state):
    """conv_in: (B,S,Cd); conv_state: (B, cw-1, Cd). -> (out, new_state)."""
    cw = cfg.ssm.conv_width
    full = jnp.concatenate([conv_state.astype(conv_in.dtype), conv_in], 1)
    S = conv_in.shape[1]
    out = sum(full[:, i:i + S] * p["conv_w"][i][None, None]
              for i in range(cw))
    out = jax.nn.silu(out + p["conv_b"][None, None])
    return out, full[:, -(cw - 1):]


def _mamba_ssm_inputs(p, cfg, xc, Bc, Cc, dt):
    s = cfg.ssm
    E, H, _ = mamba_dims(cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    log_decay = -jnp.exp(p["A_log"]) * dt                      # (B,S,H)
    xh = xc.reshape(xc.shape[:-1] + (H, s.head_dim))
    v = xh * dt[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(Bc[..., None, :],
                         Bc.shape[:-1] + (H, s.state_dim))
    q = jnp.broadcast_to(Cc[..., None, :],
                         Cc.shape[:-1] + (H, s.state_dim))
    return q, k, v, log_decay, xh


def _mamba_out(p, cfg, o, xh, z):
    E, H, _ = mamba_dims(cfg)
    o = o + (p["D_skip"][..., None] * xh.astype(jnp.float32)).astype(o.dtype)
    o = o.reshape(o.shape[:-2] + (E,))
    o = L.rms_norm(o * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", o, p["w_out"])


def mamba_state_shapes(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    E, H, conv_dim = mamba_dims(cfg)
    return ((batch, s.conv_width - 1, conv_dim),
            (batch, H, s.state_dim, s.head_dim))


def apply_mamba(p, cfg: ModelConfig, x, *, state=None,
                return_state: bool = False):
    """x: (B, S, D). state: (conv_state, ssm_state) or None."""
    B = x.shape[0]
    cs_shape, _ = mamba_state_shapes(cfg, B)
    conv_state = state[0] if state is not None else jnp.zeros(cs_shape,
                                                              x.dtype)
    ssm_state = state[1] if state is not None else None
    z, xin, Bc, Cc, dt = _mamba_proj(p, cfg, x)
    conv_in = jnp.concatenate([xin, Bc, Cc], -1)
    conv_out, conv_state = _causal_conv(p, cfg, conv_in, conv_state)
    E = cfg.ssm.expand * cfg.d_model
    N = cfg.ssm.state_dim
    xc, Bc, Cc = jnp.split(conv_out, [E, E + N], axis=-1)
    q, k, v, log_decay, xh = _mamba_ssm_inputs(p, cfg, xc, Bc, Cc, dt)
    o, ssm_state = gla_ops.gla(q, k, v, log_decay, chunk=cfg.ssm.chunk,
                               initial_state=ssm_state)
    y = _mamba_out(p, cfg, o, xh, z)
    if return_state:
        return y, (conv_state, ssm_state)
    return y


def apply_mamba_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """One token. x: (B,1,D); returns (y, conv_state, ssm_state)."""
    z, xin, Bc, Cc, dt = _mamba_proj(p, cfg, x)
    conv_in = jnp.concatenate([xin, Bc, Cc], -1)
    conv_out, conv_state = _causal_conv(p, cfg, conv_in, conv_state)
    E, N = cfg.ssm.expand * cfg.d_model, cfg.ssm.state_dim
    xc, Bc, Cc = jnp.split(conv_out, [E, E + N], axis=-1)
    q, k, v, log_decay, xh = _mamba_ssm_inputs(p, cfg, xc, Bc, Cc, dt)
    o, ssm_state = gla_ops.gla_step(q[:, 0], k[:, 0], v[:, 0],
                                    log_decay[:, 0], ssm_state)
    y = _mamba_out(p, cfg, o[:, None], xh, z)
    return y, conv_state, ssm_state


# -------------------------------------------------------------------- RWKV6

def rwkv_dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


def init_rwkv_tmix(key, cfg: ModelConfig, dtype):
    r = cfg.rwkv
    D = cfg.d_model
    H, hd = rwkv_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "mu_x": jnp.full((D,), 0.5, jnp.float32),
        "mu": jnp.full((5, D), 0.5, jnp.float32),
        "mix_w1": L.dense_init(ks[0], (D, 5 * r.mix_lora), (0,), jnp.float32),
        "mix_w2": jnp.zeros((5, r.mix_lora, D), jnp.float32),
        "w0": jnp.linspace(-6.0, 0.0, D).astype(jnp.float32),
        "w1": L.dense_init(ks[1], (D, r.decay_lora), (0,), jnp.float32),
        "w2": jnp.zeros((r.decay_lora, D), jnp.float32),
        "u": (jax.random.normal(ks[2], (H, hd)) * 0.1).astype(jnp.float32),
        "wr": L.dense_init(ks[3], (D, D), (0,), dtype),
        "wk": L.dense_init(ks[4], (D, D), (0,), dtype),
        "wv": L.dense_init(ks[5], (D, D), (0,), dtype),
        "wg": L.dense_init(ks[6], (D, D), (0,), dtype),
        "ln_x": {"scale": jnp.ones((H, hd), jnp.float32),
                 "bias": jnp.zeros((H, hd), jnp.float32)},
        "wo": L.dense_init(ks[7], (D, D), (0,), dtype),
    }


def init_rwkv_cmix(key, cfg: ModelConfig, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "wk": L.dense_init(ks[0], (D, F), (0,), dtype),
        "wv": L.dense_init(ks[1], (F, D), (0,), dtype),
        "wr": L.dense_init(ks[2], (D, D), (0,), dtype),
    }


def _shift(x, x_prev):
    """Token shift: y_t = x_{t-1}; y_0 = x_prev (B,1,D) carry."""
    return jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)


def apply_rwkv_tmix(p, cfg: ModelConfig, x, *, shift_state=None,
                    wkv_state=None, return_state: bool = False):
    """x: (B, S, D). shift_state: (B,1,D); wkv_state: (B,H,hd,hd)."""
    r = cfg.rwkv
    B, S, D = x.shape
    H, hd = rwkv_dims(cfg)
    if shift_state is None:
        shift_state = jnp.zeros((B, 1, D), x.dtype)
    xx = _shift(x, shift_state) - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    mix = jnp.tanh(jnp.einsum("bsd,dm->bsm", xxx, p["mix_w1"].astype(x.dtype)))
    mix = mix.reshape(B, S, 5, r.mix_lora)
    mix = jnp.einsum("bsfm,fmd->bsfd", mix, p["mix_w2"].astype(x.dtype))
    mix = mix + p["mu"].astype(x.dtype)[None, None]
    xw, xk, xv, xr, xg = [x + xx * mix[:, :, i] for i in range(5)]
    rr = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    # decay LoRA output sharded on D over `model` (H-major blocks align
    # with the GLA head sharding); without this the backward all-reduces a
    # replicated (B,S,D) cotangent per layer (§Perf C1)
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", xw,
                                          p["w1"].astype(x.dtype))),
                      p["w2"].astype(x.dtype))
    lora = constrain(lora, "batch", None, "model")
    log_w = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    log_w = constrain(log_w, "batch", None, "model")
    log_w = log_w.reshape(B, S, H, hd)
    o, wkv_state = gla_ops.gla(rr, k, v, log_w, bonus=p["u"], strict=True,
                               chunk=r.chunk, initial_state=wkv_state)
    o = L.group_norm_heads(o, p["ln_x"]["scale"], p["ln_x"]["bias"],
                           cfg.norm_eps)
    o = o.reshape(B, S, D) * g
    y = jnp.einsum("bsd,de->bse", o, p["wo"])
    if return_state:
        return y, (x[:, -1:], wkv_state)
    return y


def apply_rwkv_tmix_decode(p, cfg: ModelConfig, x, shift_state, wkv_state):
    """One token. x: (B,1,D). Returns (y, new_shift, new_wkv)."""
    r = cfg.rwkv
    B, _, D = x.shape
    H, hd = rwkv_dims(cfg)
    xx = shift_state.astype(x.dtype) - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    mix = jnp.tanh(jnp.einsum("bsd,dm->bsm", xxx, p["mix_w1"].astype(x.dtype)))
    mix = mix.reshape(B, 1, 5, r.mix_lora)
    mix = jnp.einsum("bsfm,fmd->bsfd", mix, p["mix_w2"].astype(x.dtype))
    mix = mix + p["mu"].astype(x.dtype)[None, None]
    xw, xk, xv, xr, xg = [x + xx * mix[:, :, i] for i in range(5)]
    rr = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, H, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, H, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))[:, 0]
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", xw,
                                          p["w1"].astype(x.dtype))),
                      p["w2"].astype(x.dtype))
    log_w = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    o, wkv_state = gla_ops.gla_step(rr, k, v, log_w.reshape(B, H, hd),
                                    wkv_state, bonus=p["u"], strict=True)
    o = L.group_norm_heads(o, p["ln_x"]["scale"], p["ln_x"]["bias"],
                           cfg.norm_eps)
    o = o.reshape(B, D) * g
    y = jnp.einsum("bd,de->be", o, p["wo"])[:, None]
    return y, x, wkv_state


def apply_rwkv_cmix(p, cfg: ModelConfig, x, *, shift_state=None,
                    return_state: bool = False):
    B, S, D = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((B, 1, D), x.dtype)
    xx = _shift(x, shift_state) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    v = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    y = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * v
    if return_state:
        return y, x[:, -1:]
    return y


def apply_rwkv_cmix_decode(p, cfg: ModelConfig, x, shift_state):
    xx = shift_state.astype(x.dtype) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    v = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    y = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * v
    return y, x
