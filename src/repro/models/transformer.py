"""Decoder LM assembly: dense / MoE / VLM (DecoderLM), hybrid Mamba2+shared
attention (ZambaLM), and attention-free RWKV6 (RWKVLM).

All stacks scan over stacked per-layer params (small HLO, fast compile) with a
configurable remat policy. Every model exposes:

    init(key) -> params
    forward(params, batch) -> final hidden states
    loss(params, batch) -> (loss, metrics)
    init_cache(batch_size, max_seq) -> decode cache
    prefill(params, batch, max_seq) -> (last-token logits, cache)
    decode_step(params, cache, token, pos) -> (logits, new cache)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.sharding.act import constrain

f32 = jnp.float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


# =====================================================================
# Generic decoder block (attention-or-MLA mixer, MLP-or-MoE ffn)
# =====================================================================

def init_block(key, cfg: ModelConfig, *, ffn: str):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.init_rms(cfg.d_model), "ln2": L.init_rms(cfg.d_model)}
    if cfg.post_norm:
        p["ln1_post"] = L.init_rms(cfg.d_model)
        p["ln2_post"] = L.init_rms(cfg.d_model)
    p["mixer"] = (A.init_mla(k1, cfg, dt) if cfg.mla is not None
                  else A.init_gqa(k1, cfg, dt))
    if ffn == "moe":
        p["ffn"] = M.init_moe(k2, cfg, dt)
    else:
        d_ff = cfg.moe.dense_d_ff if (cfg.moe and ffn == "dense_prefix") \
            else cfg.d_ff
        p["ffn"] = L.init_mlp(k2, cfg.d_model, d_ff, cfg.act, dt)
    return p


def apply_block(p, cfg: ModelConfig, x, positions, *, ffn: str,
                window=None, return_kv: bool = False):
    x = constrain(x, "batch", None, None)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    kv = None
    if cfg.mla is not None:
        out = A.apply_mla(p["mixer"], cfg, h, positions, return_kv=return_kv)
    else:
        out = A.apply_gqa(p["mixer"], cfg, h, positions, window=window,
                          return_kv=return_kv)
    if return_kv:
        out, kv = out
    if cfg.post_norm:
        out = L.rms_norm(out, p["ln1_post"], cfg.norm_eps)
    x = x + out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), f32)
    if ffn == "moe":
        out, aux = M.apply_moe(p["ffn"], cfg, h)
    else:
        out = L.apply_mlp(p["ffn"], h, cfg.act)
    if cfg.post_norm:
        out = L.rms_norm(out, p["ln2_post"], cfg.norm_eps)
    return x + out, aux, kv


def apply_block_decode(p, cfg: ModelConfig, x, cache, pos, *, ffn: str,
                       window=None):
    x = constrain(x, "batch", None, None)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        out, ckv, krope = A.apply_mla_decode(p["mixer"], cfg, h,
                                             cache["ckv"], cache["krope"],
                                             pos)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        out, kc, vc = A.apply_gqa_decode(p["mixer"], cfg, h, cache["k"],
                                         cache["v"], pos, window=window)
        new_cache = {"k": kc, "v": vc}
    if cfg.post_norm:
        out = L.rms_norm(out, p["ln1_post"], cfg.norm_eps)
    x = x + out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn == "moe":
        out, _ = M.apply_moe(p["ffn"], cfg, h, no_drop=True)
    else:
        out = L.apply_mlp(p["ffn"], h, cfg.act)
    if cfg.post_norm:
        out = L.rms_norm(out, p["ln2_post"], cfg.norm_eps)
    return x + out, new_cache


def _attn_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    a = cfg.attn
    dt = _dtype(cfg)
    if cfg.mla is not None:
        return {"ckv": ((batch, max_seq, cfg.mla.kv_lora_rank), dt),
                "krope": ((batch, max_seq, cfg.mla.rope_head_dim), dt)}
    return {"k": ((batch, max_seq, a.num_kv_heads, a.head_dim), dt),
            "v": ((batch, max_seq, a.num_kv_heads, a.head_dim), dt)}


def _pad_kv_to(kv, max_seq: int, axis: int = 1):
    """Pad the sequence axis to max_seq. axis=1 for per-layer (B, S, ...)
    caches, axis=2 for scan-stacked (L, B, S, ...) caches."""
    def pad(x):
        cfgp = [(0, 0)] * x.ndim
        cfgp[axis] = (0, max_seq - x.shape[axis])
        return jnp.pad(x, cfgp)
    return jax.tree.map(pad, kv)


# =====================================================================
# DecoderLM: dense / moe / vlm
# =====================================================================

class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg = cfg
        self.n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
        self.n_stack = cfg.num_layers - self.n_prefix
        self.stack_ffn = "moe" if cfg.moe else "mlp"

    # ---------------- params
    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 4)
        p = {"embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
             "final_norm": L.init_rms(cfg.d_model)}
        if not cfg.tie_embeddings:
            p["lm_head"] = L.embed_init(keys[1], cfg.vocab_size, cfg.d_model,
                                        dt)
        for i in range(self.n_prefix):
            p[f"prefix_{i}"] = init_block(jax.random.fold_in(keys[2], i),
                                          cfg, ffn="dense_prefix")
        p["stack"] = _stack_init(
            functools.partial(init_block, cfg=cfg, ffn=self.stack_ffn),
            keys[3], self.n_stack)
        return p

    def _head(self, p):
        return p["embed"] if self.cfg.tie_embeddings else p["lm_head"]

    def _windows(self):
        """Per-stack-layer window values (gemma2 local/global alternation)."""
        cfg = self.cfg
        if cfg.attn is None or cfg.attn.pattern != "local_global":
            return None
        idx = jnp.arange(self.n_stack) + self.n_prefix
        return jnp.where(idx % 2 == 0, cfg.attn.window, A.GLOBAL_WINDOW)

    def _embed(self, p, tokens, vision_embeds=None):
        cfg = self.cfg
        x = p["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.family == "vlm" and vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        return x

    # ---------------- full-sequence
    def forward(self, p, tokens, vision_embeds=None, *, collect_kv=False):
        cfg = self.cfg
        x = self._embed(p, tokens, vision_embeds)
        positions = jnp.arange(x.shape[1])
        windows = self._windows()
        aux = jnp.zeros((), f32)
        prefix_kv = []
        for i in range(self.n_prefix):
            x, a, kv = apply_block(p[f"prefix_{i}"], cfg, x, positions,
                                   ffn="dense_prefix", return_kv=collect_kv)
            aux = aux + a
            prefix_kv.append(kv)

        def body(carry, inp):
            x, aux = carry
            lp = inp[0]
            w = inp[1] if windows is not None else None
            x, a, kv = apply_block(lp, cfg, x, positions, ffn=self.stack_ffn,
                                   window=w, return_kv=collect_kv)
            return (x, aux + a), kv

        xs = (p["stack"],) if windows is None else (p["stack"], windows)
        (x, aux), stack_kv = jax.lax.scan(_remat(body, cfg), (x, aux), xs)
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        if collect_kv:
            return x, aux, (prefix_kv, stack_kv)
        return x, aux

    def loss(self, p, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        ve = batch.get("vision_embeds")
        x, aux = self.forward(p, inputs, ve)
        if cfg.family == "vlm":
            tv = cfg.vision_tokens
            x = x[:, tv - 1:tv - 1 + labels.shape[1]]
        loss, metrics = L.chunked_xent(x, self._head(p), labels,
                                       logit_softcap=cfg.logit_softcap)
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    # ---------------- decode
    def init_cache(self, batch: int, max_seq: int):
        shapes = _attn_cache_shapes(self.cfg, batch, max_seq)
        cache = {"stack": {k: jnp.zeros((self.n_stack,) + sh, dt)
                           for k, (sh, dt) in shapes.items()}}
        for i in range(self.n_prefix):
            cache[f"prefix_{i}"] = {k: jnp.zeros(sh, dt)
                                    for k, (sh, dt) in shapes.items()}
        return cache

    def prefill(self, p, batch, max_seq: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        ve = batch.get("vision_embeds")
        x, _, (prefix_kv, stack_kv) = self.forward(p, tokens, ve,
                                                   collect_kv=True)
        cache = {}
        names = ("ckv", "krope") if cfg.mla is not None else ("k", "v")
        for i, kv in enumerate(prefix_kv):
            cache[f"prefix_{i}"] = _pad_kv_to(dict(zip(names, kv)), max_seq,
                                              axis=1)
        cache["stack"] = _pad_kv_to(dict(zip(names, stack_kv)), max_seq,
                                    axis=2)
        logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(f32),
                            self._head(p).astype(f32))
        logits = L.softcap(logits, cfg.logit_softcap)
        return logits, cache

    def decode_step(self, p, cache, token, pos):
        """token: (B,) int32; pos: scalar int32 (cache fill position)."""
        cfg = self.cfg
        x = self._embed(p, token[:, None])
        windows = self._windows()
        for i in range(self.n_prefix):
            x, nc = apply_block_decode(p[f"prefix_{i}"], cfg, x,
                                       cache[f"prefix_{i}"], pos,
                                       ffn="dense_prefix")
            cache[f"prefix_{i}"] = nc

        def body(x, inp):
            if windows is not None:
                lp, lc, w = inp
            else:
                (lp, lc), w = inp, None
            x, nc = apply_block_decode(lp, cfg, x, lc, pos,
                                       ffn=self.stack_ffn, window=w)
            return x, nc

        xs = ((p["stack"], cache["stack"]) if windows is None
              else (p["stack"], cache["stack"], windows))
        x, new_stack = jax.lax.scan(body, x, xs)
        cache = dict(cache)
        cache["stack"] = new_stack
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(f32),
                            self._head(p).astype(f32))
        return L.softcap(logits, cfg.logit_softcap), cache


# =====================================================================
# ZambaLM: Mamba2 backbone + shared attention block (hybrid)
# =====================================================================

class ZambaLM:
    """``num_layers`` Mamba2 layers; a single weight-shared transformer block
    is applied after every ``attn_every`` Mamba2 layers (grouped scan)."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "hybrid"
        self.cfg = cfg
        self.m = cfg.attn_every
        self.n_groups = cfg.num_layers // self.m
        self.n_trail = cfg.num_layers - self.n_groups * self.m

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 5)
        init_m = lambda k: {"ln": L.init_rms(cfg.d_model),
                            "mamba": S.init_mamba(k, cfg, dt)}
        p = {
            "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": L.init_rms(cfg.d_model),
            "lm_head": L.embed_init(keys[1], cfg.vocab_size, cfg.d_model, dt),
            "groups": jax.vmap(lambda ks: jax.vmap(init_m)(ks))(
                jax.random.split(keys[2],
                                 self.n_groups * self.m
                                 ).reshape(self.n_groups, self.m, 2)),
            "shared": init_block(keys[3], cfg, ffn="mlp"),
        }
        if self.n_trail:
            p["trail"] = _stack_init(init_m, keys[4], self.n_trail)
        return p

    def _mamba_layer(self, lp, x, state=None, want_state=False):
        x = constrain(x, "batch", None, None)
        h = L.rms_norm(x, lp["ln"], self.cfg.norm_eps)
        if want_state:
            y, st = S.apply_mamba(lp["mamba"], self.cfg, h, state=state,
                                  return_state=True)
            return x + y, st
        return x + S.apply_mamba(lp["mamba"], self.cfg, h), None

    def forward(self, p, tokens, *, collect=False):
        cfg = self.cfg
        x = p["embed"][tokens]
        positions = jnp.arange(x.shape[1])

        def group(carry, inp):
            x = carry
            gp = inp

            def inner(x, lp):
                x, st = self._mamba_layer(lp, x, want_state=collect)
                return x, st

            x, states = jax.lax.scan(inner, x, gp)
            x, _, kv = apply_block(p["shared"], cfg, x, positions, ffn="mlp",
                                   return_kv=collect)
            return x, (states, kv)

        x, (g_states, g_kv) = jax.lax.scan(_remat(group, cfg), x,
                                           p["groups"])
        t_states = None
        if self.n_trail:
            def inner(x, lp):
                x, st = self._mamba_layer(lp, x, want_state=collect)
                return x, st
            x, t_states = jax.lax.scan(inner, x, p["trail"])
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        if collect:
            return x, (g_states, g_kv, t_states)
        return x

    def loss(self, p, batch):
        tokens = batch["tokens"]
        x = self.forward(p, tokens[:, :-1])
        loss, metrics = L.chunked_xent(x, p["lm_head"], tokens[:, 1:])
        return loss, metrics

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        cs, ss = S.mamba_state_shapes(cfg, batch)
        ash = _attn_cache_shapes(cfg, batch, max_seq)
        return {
            "g_conv": jnp.zeros((self.n_groups, self.m) + cs, dt),
            "g_ssm": jnp.zeros((self.n_groups, self.m) + ss, f32),
            "t_conv": jnp.zeros((self.n_trail,) + cs, dt),
            "t_ssm": jnp.zeros((self.n_trail,) + ss, f32),
            "attn": {k: jnp.zeros((self.n_groups,) + sh, d)
                     for k, (sh, d) in ash.items()},
        }

    def prefill(self, p, batch, max_seq: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        x, (g_states, g_kv, t_states) = self.forward(p, tokens, collect=True)
        cache = {
            "g_conv": g_states[0], "g_ssm": g_states[1],
            "t_conv": (t_states[0] if self.n_trail
                       else jnp.zeros((0,), _dtype(cfg))),
            "t_ssm": (t_states[1] if self.n_trail
                      else jnp.zeros((0,), f32)),
            "attn": _pad_kv_to(dict(zip(("k", "v"), g_kv)), max_seq, axis=2),
        }
        logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(f32),
                            p["lm_head"].astype(f32))
        return logits, cache

    def decode_step(self, p, cache, token, pos):
        cfg = self.cfg
        x = p["embed"][token[:, None]]

        def group(x, inp):
            gp, conv, ssm, kc, vc = inp

            def inner(x, lin):
                lp, cst, sst = lin
                h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
                y, ncst, nsst = S.apply_mamba_decode(lp["mamba"], cfg, h,
                                                     cst, sst)
                return x + y, (ncst, nsst)

            x, (nconv, nssm) = jax.lax.scan(inner, x, (gp, conv, ssm))
            x, ncache = apply_block_decode(p["shared"], cfg, x,
                                           {"k": kc, "v": vc}, pos,
                                           ffn="mlp")
            return x, (nconv, nssm, ncache["k"], ncache["v"])

        x, (g_conv, g_ssm, ak, av) = jax.lax.scan(
            group, x, (p["groups"], cache["g_conv"], cache["g_ssm"],
                       cache["attn"]["k"], cache["attn"]["v"]))
        t_conv, t_ssm = cache["t_conv"], cache["t_ssm"]
        if self.n_trail:
            def inner(x, lin):
                lp, cst, sst = lin
                h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
                y, ncst, nsst = S.apply_mamba_decode(lp["mamba"], cfg, h,
                                                     cst, sst)
                return x + y, (ncst, nsst)
            x, (t_conv, t_ssm) = jax.lax.scan(inner, x,
                                              (p["trail"], cache["t_conv"],
                                               cache["t_ssm"]))
        x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(f32),
                            p["lm_head"].astype(f32))
        return logits, {"g_conv": g_conv, "g_ssm": g_ssm, "t_conv": t_conv,
                        "t_ssm": t_ssm, "attn": {"k": ak, "v": av}}


# =====================================================================
# RWKVLM: attention-free (rwkv6)
# =====================================================================

class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "ssm"
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 4)

        def init_layer(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": L.init_ln(cfg.d_model),
                    "ln2": L.init_ln(cfg.d_model),
                    "tmix": S.init_rwkv_tmix(k1, cfg, dt),
                    "cmix": S.init_rwkv_cmix(k2, cfg, dt)}

        return {
            "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "ln0": L.init_ln(cfg.d_model),
            "final_norm": L.init_ln(cfg.d_model),
            "lm_head": L.embed_init(keys[1], cfg.vocab_size, cfg.d_model, dt),
            "stack": _stack_init(init_layer, keys[2], cfg.num_layers),
        }

    def forward(self, p, tokens, *, collect=False):
        cfg = self.cfg
        x = p["embed"][tokens]
        x = L.layer_norm(x, p["ln0"]["scale"], p["ln0"]["bias"],
                         cfg.norm_eps)

        def body(x, lp):
            x = constrain(x, "batch", None, None)
            h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"],
                             cfg.norm_eps)
            if collect:
                y, (sh_t, wkv) = S.apply_rwkv_tmix(lp["tmix"], cfg, h,
                                                   return_state=True)
            else:
                y = S.apply_rwkv_tmix(lp["tmix"], cfg, h)
                sh_t = wkv = jnp.zeros((), f32)
            x = x + y
            h = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"],
                             cfg.norm_eps)
            if collect:
                y, sh_c = S.apply_rwkv_cmix(lp["cmix"], cfg, h,
                                            return_state=True)
            else:
                y = S.apply_rwkv_cmix(lp["cmix"], cfg, h)
                sh_c = jnp.zeros((), f32)
            return x + y, (sh_t, wkv, sh_c)

        x, states = jax.lax.scan(_remat(body, cfg), x, p["stack"])
        x = L.layer_norm(x, p["final_norm"]["scale"], p["final_norm"]["bias"],
                         cfg.norm_eps)
        if collect:
            return x, states
        return x

    def loss(self, p, batch):
        tokens = batch["tokens"]
        x = self.forward(p, tokens[:, :-1])
        return L.chunked_xent(x, p["lm_head"], tokens[:, 1:])

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        H, hd = S.rwkv_dims(cfg)
        Lx = cfg.num_layers
        dt = _dtype(cfg)
        return {"wkv": jnp.zeros((Lx, batch, H, hd, hd), f32),
                "shift_t": jnp.zeros((Lx, batch, 1, cfg.d_model), dt),
                "shift_c": jnp.zeros((Lx, batch, 1, cfg.d_model), dt)}

    def prefill(self, p, batch, max_seq: int):
        x, (sh_t, wkv, sh_c) = self.forward(p, batch["tokens"], collect=True)
        cache = {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}
        logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(f32),
                            p["lm_head"].astype(f32))
        return logits, cache

    def decode_step(self, p, cache, token, pos):
        cfg = self.cfg
        x = p["embed"][token[:, None]]
        x = L.layer_norm(x, p["ln0"]["scale"], p["ln0"]["bias"], cfg.norm_eps)

        def body(x, inp):
            lp, wkv, sh_t, sh_c = inp
            h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"],
                             cfg.norm_eps)
            y, nsh_t, nwkv = S.apply_rwkv_tmix_decode(lp["tmix"], cfg, h,
                                                      sh_t, wkv)
            x = x + y
            h = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"],
                             cfg.norm_eps)
            y, nsh_c = S.apply_rwkv_cmix_decode(lp["cmix"], cfg, h, sh_c)
            return x + y, (nwkv, nsh_t, nsh_c)

        x, (wkv, sh_t, sh_c) = jax.lax.scan(
            body, x, (p["stack"], cache["wkv"], cache["shift_t"],
                      cache["shift_c"]))
        x = L.layer_norm(x, p["final_norm"]["scale"],
                         p["final_norm"]["bias"], cfg.norm_eps)
        logits = jnp.einsum("bd,vd->bv", x[:, 0].astype(f32),
                            p["lm_head"].astype(f32))
        return logits, {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}
