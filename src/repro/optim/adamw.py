"""AdamW with fp32 moments, global-norm clipping, and decoupled weight decay.

State is a plain pytree (checkpoint-friendly, shardable with the param rules
widened across the ``pod`` axis — see repro.sharding). ``master=False`` keeps
no fp32 master copy (bf16 params updated with fp32 math), which is what the
largest assigned config (deepseek-v2-236b) uses to fit HBM; smaller models
can enable masters.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master: bool = False


def schedule(cfg: AdamWConfig, step):
    step = step.astype(f32)
    warm = cfg.peak_lr * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, f32)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.master:
        state["master"] = jax.tree.map(lambda p: p.astype(f32), params)
    return state


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(f32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    t = step.astype(f32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v, mw=None):
        g = g.astype(f32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        base = (mw if mw is not None else p.astype(f32))
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new = base - lr * (step_vec + decay * base)
        return new, m, v

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])
    leaves_mw = (treedef.flatten_up_to(state["master"])
                 if cfg.master else [None] * len(leaves_p))
    new_p, new_m, new_v, new_mw = [], [], [], []
    for p, g, m, v, mw in zip(leaves_p, leaves_g, leaves_m, leaves_v,
                              leaves_mw):
        np_, nm, nv = upd(p, g, m, v, mw)
        new_p.append(np_.astype(p.dtype))
        new_m.append(nm)
        new_v.append(nv)
        if cfg.master:
            new_mw.append(np_)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    if cfg.master:
        new_state["master"] = jax.tree.unflatten(treedef, new_mw)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return jax.tree.unflatten(treedef, new_p), new_state, metrics
