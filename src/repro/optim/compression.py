"""Error-feedback int8 gradient compression (distributed-optimization trick).

For cross-pod (DCN) gradient reduction, 4x smaller payloads matter. Each
gradient leaf is quantized to int8 with a per-leaf scale; the quantization
residual is carried in an error-feedback buffer so the compression is
unbiased over time (Seide et al. / EF-SGD style). The compressed
representative is what a production runner would all-reduce over DCN; here
compress/decompress wrap the gradient tree inside train_step when enabled.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, ef):
    """Returns ((int8 tree, scales tree), new error feedback)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.clip(jnp.max(jnp.abs(g)), 1e-12, None) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - q.astype(jnp.float32) * scale
        return q, scale, err

    flat, treedef = jax.tree_util.tree_flatten(grads)
    ef_flat = treedef.flatten_up_to(ef)
    qs, scales, errs = [], [], []
    for g, e in zip(flat, ef_flat):
        q, s, err = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(err)
    unf = jax.tree_util.tree_unflatten
    return (unf(treedef, qs), unf(treedef, scales)), unf(treedef, errs)


def decompress(qtree, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qtree,
                        scales)


def roundtrip(grads, ef) -> Tuple:
    """compress+decompress (what the DCN all-reduce would transport)."""
    (q, s), ef = compress(grads, ef)
    return decompress(q, s), ef
