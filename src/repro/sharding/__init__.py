from repro.sharding.partition import (batch_axes, batch_pspecs, cache_pspecs,
                                      mesh_axes, opt_pspecs, param_pspecs,
                                      shardings)

__all__ = ["batch_axes", "batch_pspecs", "cache_pspecs", "mesh_axes",
           "opt_pspecs", "param_pspecs", "shardings"]
