"""Activation-sharding constraints that models can apply without knowing the
mesh.

XLA's sharding propagation through ``while`` loops (scan over layers, query
chunks, loss chunks) can drop activation shardings and silently replicate the
batch across the model axis. The fix is explicit anchors inside scan bodies.
Models call ``constrain(x, 'batch', None, 'model', None)``; the launcher
activates a context mapping 'batch'/'model' to concrete mesh axes. Without an
active context (unit tests, single-device runs) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_sharding",
                                                      default=None)


@contextlib.contextmanager
def activation_sharding(mesh):
    """Activate activation constraints for a mesh with a 'model' axis and
    'data' (+ optional 'pod') batch axes."""
    axes = tuple(mesh.axis_names)
    batch = ("pod", "data") if "pod" in axes else ("data",)
    ctx = {
        "batch": batch,
        "batch_size": int(__import__("numpy").prod(
            [mesh.shape[a] for a in batch])),
        "model": "model",
        "model_size": int(mesh.shape["model"]),
        "mesh": mesh,
    }
    token = _CTX.set(ctx)
    try:
        yield
    finally:
        _CTX.reset(token)


def active() -> bool:
    return _CTX.get() is not None


def current_mesh():
    c = _CTX.get()
    return c["mesh"] if c else None


def batch_shards() -> int:
    """Number of ways the batch axes shard the leading dim (1 if inactive)."""
    c = _CTX.get()
    return c["batch_size"] if c else 1


def constrain(x, *dims):
    """dims entries: 'batch' | 'model' | None, one per array dim.
    Dims whose size does not divide the named axis are left unconstrained."""
    c = _CTX.get()
    if c is None or x is None or not hasattr(x, "ndim"):
        return x
    if x.ndim != len(dims):
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "batch" and x.shape[i] % c["batch_size"] == 0:
            spec.append(c["batch"])
        elif d == "model" and x.shape[i] % c["model_size"] == 0:
            spec.append(c["model"])
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:       # no ambient mesh (e.g. eager test) -> no-op
        return x


def constrain_tree(tree, *dims):
    return jax.tree.map(lambda x: constrain(x, *dims), tree)
