"""Partitioning rules: params / optimizer state / batches / decode caches.

Mesh axes:
  - ``model``: tensor/expert parallel (attention heads, ffn, vocab, experts)
  - ``data``:  data parallel + FSDP for parameters
  - ``pod``:   (multi-pod only) extra data-parallel axis across pods; params
    are pod-replicated, optimizer state is additionally sharded over ``pod``
    (cross-pod ZeRO — cheap DCN traffic only at the optimizer step).

Rules are name/path based over the param trees produced by repro.models.
Leading stacking axes (scan over layers / groups) are unsharded.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh):
    ax = mesh_axes(mesh)
    return ("pod", "data") if "pod" in ax else ("data",)


def _tp(mesh: Mesh) -> int:
    return mesh.shape["model"]


FSDP = "data"
TP = "model"


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            out.append(e.name)
    return tuple(out)


def _core_spec(names: Tuple[str, ...], shape, tp: int,
               cfg: ModelConfig) -> Tuple:
    """PartitionSpec entries for the trailing (core) dims of a param."""
    name = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    in_moe = (parent == "ffn" and cfg.moe is not None
              and "shared" not in names
              and not any(n.startswith("prefix") for n in names))

    def div(n):   # shardable on model axis?
        return n % tp == 0

    if name in ("embed", "lm_head"):
        return (TP, FSDP)
    if name in ("wq", "wk", "wv") and parent in ("mixer", "attn", "self",
                                                 "cross"):
        heads = shape[-2]
        return (FSDP, TP if div(heads) else None, None)
    if name == "wo" and parent in ("mixer", "attn", "self", "cross"):
        heads = shape[-3]
        return (TP if div(heads) else None, None, FSDP)
    if name in ("wq_a", "wkv_a"):
        return (FSDP, None)
    if name in ("wq_b", "wk_b", "wv_b"):
        return (FSDP, TP if div(shape[-2]) else None, None)
    if name == "router":
        return (FSDP, None)
    if name == "wi" and in_moe:            # (E, D, 2F)
        return (TP if div(shape[-3]) else None, FSDP, None)
    if name == "wo" and in_moe:            # (E, F, D)
        return (TP if div(shape[-3]) else None, None, FSDP)
    if name == "wi":                       # dense mlp (D, {1,2}F)
        return (FSDP, TP if div(shape[-1]) else None)
    if name == "wo":                       # dense mlp (F, D)
        return (TP if div(shape[-2]) else None, FSDP)
    # --- mamba2
    if name == "w_in":
        return (FSDP, TP if div(shape[-1]) else None)
    if name == "conv_w":
        return (None, TP if div(shape[-1]) else None)
    if name == "conv_b":
        return (TP if div(shape[-1]) else None,)
    if name in ("A_log", "dt_bias", "D_skip"):
        return (TP if div(shape[-1]) else None,)
    if name == "norm" and parent == "mamba":
        return (TP if div(shape[-1]) else None,)
    if name == "w_out":                    # (E_inner, D)
        return (TP if div(shape[-2]) else None, FSDP)
    # --- rwkv6
    if parent == "tmix" and name in ("wr", "wk", "wv", "wg"):
        return (FSDP, TP if div(shape[-1]) else None)
    if parent == "tmix" and name == "wo":
        return (TP if div(shape[-2]) else None, FSDP)
    if parent == "cmix" and name == "wk":
        return (FSDP, TP if div(shape[-1]) else None)
    if parent == "cmix" and name == "wr":
        # gate path: replicated output (weight-gather only) so the gated
        # product with the post-AR value tensor stays replicated — avoids
        # per-layer (B,S,D) activation all-gathers (§Perf C1)
        return (FSDP, None)
    if parent == "cmix" and name == "wv":
        return (TP if div(shape[-2]) else None, FSDP)
    if name == "mix_w1":
        return (FSDP, None)
    if name == "w1":
        return (FSDP, None)
    # everything else (norm scales, biases, loras, u, mu, w0/w2, mix_w2):
    return tuple(None for _ in shape)


def param_pspecs(cfg: ModelConfig, param_shapes, mesh: Mesh):
    """PartitionSpec tree matching the model's param tree. Every entry is
    divisibility-sanitized against the mesh (odd vocab sizes etc. fall back
    to unsharded on that dim)."""
    tp = _tp(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        core = _core_spec(names, shape, tp, cfg)
        lead = len(shape) - len(core)
        assert lead >= 0, (names, shape, core)
        spec = (None,) * lead + tuple(core)
        clean = []
        for dim, e in zip(shape, spec):
            if e is None:
                clean.append(None)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            clean.append(e if dim % size == 0 else None)
        return P(*clean)

    return jax.tree_util.tree_map_with_path(rule, param_shapes)


def opt_pspecs(cfg: ModelConfig, param_specs, mesh: Mesh):
    """Optimizer-moment specs: param spec with FSDP axis widened to
    ('pod','data') on multi-pod meshes (cross-pod ZeRO)."""
    if "pod" not in mesh_axes(mesh):
        return param_specs

    def widen(spec: P):
        entries = []
        for e in spec:
            if e == FSDP:
                entries.append(("pod", FSDP))
            else:
                entries.append(e)
        return P(*entries)

    return jax.tree_util.tree_map(
        widen, param_specs, is_leaf=lambda x: isinstance(x, P))


def _batch_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def batch_entry(mesh: Mesh, dim: int):
    """Batch-axes spec entry iff the dim divides the batch mesh extent."""
    return batch_axes(mesh) if dim % _batch_size(mesh) == 0 else None


def batch_pspecs(batch, mesh: Mesh):
    """Shard the leading (batch) dim of every batch input."""

    def rule(leaf):
        return P(*((batch_entry(mesh, leaf.shape[0]),)
                   + (None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(rule, batch)


def cache_pspecs(cfg: ModelConfig, cache_shapes, mesh: Mesh):
    """Decode-cache specs.

    KV caches shard kv-heads on `model` when divisible, else head_dim (the
    sequence axis must stay unsharded: a ``dynamic_update_slice`` at a traced
    position on a sharded dim forces involuntary full rematerialization in
    the SPMD partitioner). MLA latent caches are small by design and are
    model-replicated. Recurrent states shard heads on `model`. Every entry
    is divisibility-guarded (long_500k has global_batch=1)."""
    tp = _tp(mesh)

    def be(dim):
        return batch_entry(mesh, dim)

    def mp(dim):
        return TP if dim % tp == 0 else None

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape
        if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
            # (..., B, S, K, H) with 0-2 leading stack dims
            lead = len(shape) - 4
            if shape[-2] % tp == 0:
                core = (be(shape[-4]), None, TP, None)
            else:
                core = (be(shape[-4]), None, None, mp(shape[-1]))
            return P(*((None,) * lead + core))
        if name in ("ckv", "krope"):
            lead = len(shape) - 3
            if cfg.flash_decode:
                # flash-decode (shard_map): sequence-sharded latent cache
                return P(*((None,) * lead + (be(shape[-3]),
                                             mp(shape[-2]), None)))
            # baseline: shard the latent dim (updates only touch S; scores
            # psum over the sharded latent contraction)
            return P(*((None,) * lead + (be(shape[-3]), None,
                                         mp(shape[-1]))))
        if "conv" in name:
            lead = len(shape) - 3          # (..., B, cw-1, conv_dim)
            return P(*((None,) * lead +
                       (be(shape[-3]), None, mp(shape[-1]))))
        if name in ("g_ssm", "t_ssm", "wkv"):
            lead = len(shape) - 4          # (..., B, H, K, V)
            return P(*((None,) * lead +
                       (be(shape[-4]), mp(shape[-3]), None, None)))
        if name in ("shift_t", "shift_c"):
            lead = len(shape) - 3          # (L, B, 1, D)
            return P(*((None,) * lead +
                       (be(shape[-3]), None, mp(shape[-1]))))
        # fallback: shard nothing
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def shardings(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
