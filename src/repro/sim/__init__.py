"""Scenario engine + batched fleet simulation (beyond-paper subsystem).

The paper validates CICS with fleet-wide rollouts under real operational
variation; this package supplies the reproduction's counterpart: a library
of declarative scenario perturbations (`scenarios`), a jit/vmap-batched
rollout engine over a (scenario x seed) axis (`engine`), a per-cluster
emissions ledger with an unshaped counterfactual run in the same batch
(`ledger`), and per-scenario summary reporting (`report`).
"""
from repro.sim.engine import (SimConfig, SimParams, SimState, make_init,
                              make_day_step, make_rollout, rollout_batch,
                              rollout_batch_sharded, rollout_sequential)
from repro.sim.ledger import Ledger, init_ledger, ledger_update, summarize
from repro.sim.scenarios import (Scenario, build_params, build_batch,
                                 default_library, forecast_bust_library,
                                 mobility_sweep_library,
                                 risk_sweep_library, MOBILITY_SWEEP,
                                 RISK_BETAS, RISK_MEMBERS)
from repro.sim.report import (scenario_rows, format_table,
                              mobility_sweep_rows, mpc_recourse_rows,
                              risk_sweep_rows, state_nbytes,
                              telemetry_rows, MOBILITY_COLUMNS,
                              MPC_COLUMNS, RISK_COLUMNS,
                              TELEMETRY_COLUMNS)
from repro.sim.telemetry import (DayTelemetry, day_telemetry,
                                 telemetry_records, write_jsonl, read_jsonl,
                                 profile_stages, format_stage_table,
                                 TRACE_FIELDS)

__all__ = [
    "SimConfig", "SimParams", "SimState", "make_init", "make_day_step",
    "make_rollout", "rollout_batch", "rollout_batch_sharded",
    "rollout_sequential",
    "Ledger", "init_ledger", "ledger_update", "summarize",
    "Scenario", "build_params", "build_batch", "default_library",
    "forecast_bust_library", "mobility_sweep_library",
    "risk_sweep_library", "MOBILITY_SWEEP", "RISK_BETAS", "RISK_MEMBERS",
    "scenario_rows", "format_table", "mobility_sweep_rows",
    "mpc_recourse_rows", "risk_sweep_rows", "state_nbytes",
    "telemetry_rows", "MOBILITY_COLUMNS", "MPC_COLUMNS", "RISK_COLUMNS",
    "TELEMETRY_COLUMNS",
    "DayTelemetry", "day_telemetry", "telemetry_records", "write_jsonl",
    "read_jsonl", "profile_stages", "format_stage_table", "TRACE_FIELDS",
]
