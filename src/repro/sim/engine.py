"""Batched fleet rollout engine: scan/vmap/shard_map over the staged core.

The CICS day cycle itself lives in ``repro.core.stages`` (pure stage
functions composed by ``stages.make_day_step``); this module owns only the
ROLLOUT machinery around it:

  * `SimConfig`            — static shapes + solver knobs. Everything
    dynamic (prices, risk, weather, outages) lives in `SimParams` arrays.
  * `make_day_step(cfg)`   — the staged day, returning (state', StepOut).
  * `make_init(cfg)`       — `lax.scan` burn-in -> SimState (jit/vmap-safe).
  * `make_rollout(cfg, d)` — `lax.scan` of the day step over days, carrying
    an emissions ledger and advancing an UNSHAPED counterfactual fleet
    (identical arrivals, VCC = machine capacity) in the same trace.
  * `rollout_batch`        — `jax.vmap` of (init + rollout) across a
    leading (scenario x seed) axis of stacked SimParams.
  * `rollout_batch_sharded`— the same batch `shard_map`'d over a 1-D
    device mesh (`launch.mesh.make_batch_mesh`): scenario batches scale
    across every accelerator on the host/pod, one shard per device group.

Parity contract (tested): a vmap'd batch reproduces each scenario's
non-batched sequential rollout BITWISE, for any batch size — and the
sharded batch reproduces the unsharded batch bitwise, for any device
count that divides it. This needs batch-invariant numerics — ordered
reductions for daily totals (`admission.hour_sum`), the elementwise
`power._solve_spd` / `power.pd_power`, and the `optimization_barrier`
pins at every stage boundary in `stages` so XLA cannot re-fuse (and
re-round) a producer when its consumers change. `rollout_sequential`
additionally drives the same jitted day step from a Python loop — a
debugging reference that agrees to float tolerance (standalone-vs-scan-
body compilation may differ in FMA choices).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.core import stages
from repro.core.stages import (SimParams, SimState,    # noqa: F401
                               StepOut, hour_sum as _hsum)
from repro.sim.ledger import DayMetrics, init_ledger, ledger_update


@dataclass(frozen=True)
class SimConfig:
    """Static structure (shapes + solver knobs). Everything dynamic —
    prices, risk, weather, outages — lives in SimParams as arrays."""
    n_clusters: int = 16
    n_campuses: int = 4
    n_zones: int = 4
    pds_per_cluster: int = 2
    hist_days: int = 35           # rolling-history window (weeks * 7)
    slo_margin: float = 1.0
    slo_pause_days: int = 7
    joint_spatial: bool = False   # True = joint spatio-temporal optimize
    #                               (static graph selection; each
    #                               scenario's mobility stays a data leaf)
    n_members: int = 1            # forecast-ensemble size K (static shape;
    #                               K > 1 turns on the CVaR risk objective
    #                               at each scenario's risk_beta)
    streaming: bool = False       # True = O(1) streaming prediction layer
    #                               (stats.PredictorState carry; state and
    #                               day-step cost independent of
    #                               hist_days — year-scale rollouts);
    #                               False = the legacy rescan graph
    #                               (golden-trace pinned)
    telemetry: bool = False       # True = stack a sim.telemetry
    #                               DayTelemetry record per day into the
    #                               rollout traj (solver convergence +
    #                               forecast calibration + SLO gauges);
    #                               False = the legacy graph, byte-
    #                               identical compiled HLO (tested)
    mpc: bool = False             # True = intra-day MPC recourse (hourly
    #                               warm-started suffix re-solves,
    #                               core.mpc); False = open-loop day-ahead
    #                               plan, byte-identical compiled HLO
    #                               (tested, same contract as telemetry)
    slo_allowance: float = 0.25   # late-arrival fraction not counted as
    #                               unmet (admission.finalize_day)

    def stage_config(self) -> stages.StageConfig:
        return stages.StageConfig(slo_margin=self.slo_margin,
                                  slo_pause_days=self.slo_pause_days,
                                  joint_spatial=self.joint_spatial,
                                  n_members=self.n_members,
                                  streaming=self.streaming,
                                  telemetry=self.telemetry,
                                  mpc=self.mpc,
                                  slo_allowance=self.slo_allowance)


def _metrics(res, cf) -> DayMetrics:
    return DayMetrics(
        carbon_kg=_hsum(res.carbon), kwh=_hsum(res.power),
        peak_kw=res.power.max(axis=-1), served=res.served,
        arrived=res.arrived, unmet=res.unmet, queue_end=res.queue_end,
        cf_carbon_kg=_hsum(cf.carbon), cf_kwh=_hsum(cf.power),
        cf_peak_kw=cf.power.max(axis=-1), cf_served=cf.served,
        cf_queue_end=cf.queue_end)


def make_day_step(cfg: SimConfig):
    """The staged CICS day (see stages.make_day_step):
    step(params, state, xs) -> (state', StepOut)."""
    return stages.make_day_step(cfg.stage_config())


def make_init(cfg: SimConfig):
    """init(params) -> burned-in SimState. jit- and vmap-compatible."""
    return stages.make_init(cfg.n_clusters, cfg.n_campuses, cfg.n_zones,
                            cfg.hist_days, streaming=cfg.streaming)


def _day_xs(params: SimParams, d=None):
    """Scenario-schedule slices. With d=None returns scan xs (leading day
    axis); with an int d returns that single day's slices.

    The intraday forecast-busting channels are included only when the
    SimParams carry them (non-None): absent keys keep the traced day-step
    graph — and its compiled HLO — exactly the legacy one."""
    sched = {"green_scale": params.green_scale,
             "coal_scale": params.coal_scale,
             "cap_scale": params.cap_scale,
             "arrival_scale": params.arrival_scale,
             "campus_scale": params.campus_scale}
    if params.arrival_hour_scale is not None:
        sched["arrival_hour_scale"] = params.arrival_hour_scale
    if params.carbon_hour_scale is not None:
        sched["carbon_hour_scale"] = params.carbon_hour_scale
    if d is None:
        return sched
    return {k: v[d] for k, v in sched.items()}


def make_rollout(cfg: SimConfig, days: int):
    """rollout(params, state) -> (state', Ledger, traj dict of (days,))."""
    step = make_day_step(cfg)

    def rollout(params: SimParams, state: SimState):
        horizon = params.cap_scale.shape[-2]
        if horizon < days:
            raise ValueError(
                f"params schedules cover {horizon} days but the rollout "
                f"asks for {days}; rebuild with build_params(..., "
                f"days>={days})")
        ledger = init_ledger(cfg.n_clusters)

        def body(carry, xs):
            s, led = carry
            s, out = step(params, s, xs)
            metrics = _metrics(out.res, out.cf)
            led = ledger_update(led, metrics)
            traj = {"carbon_kg": _hsum(metrics.carbon_kg),
                    "cf_carbon_kg": _hsum(metrics.cf_carbon_kg),
                    "kwh": _hsum(metrics.kwh),
                    "peak_kw": _hsum(metrics.peak_kw),
                    "queue": _hsum(metrics.queue_end)}
            if cfg.telemetry:
                # stacked by the scan -> (days, ...) DayTelemetry leaves
                # (telemetry=False keeps the traj keys — and graph —
                # exactly the legacy ones)
                traj["telemetry"] = out.telemetry
            return (s, led), traj

        xs = jax.tree.map(lambda a: a[:days], _day_xs(params))
        (state, ledger), traj = jax.lax.scan(body, (state, ledger), xs)
        return state, ledger, traj

    return rollout


def rollout_batch(cfg: SimConfig, days: int):
    """vmap'd (init + rollout) over a leading (scenario x seed) axis."""
    init = make_init(cfg)
    roll = make_rollout(cfg, days)

    @jax.jit
    def run(params: SimParams):
        def one(p):
            return roll(p, init(p))
        return jax.vmap(one)(params)

    return run


def rollout_batch_sharded(cfg: SimConfig, days: int, mesh=None):
    """`rollout_batch` with the (scenario x seed) batch axis sharded over
    a 1-D device mesh (`launch.mesh.make_batch_mesh()` over all local
    devices by default). Each device runs its vmap'd slice of the batch;
    there is no cross-rollout communication, so the result is bitwise
    identical to the unsharded `rollout_batch` (parity-tested).

    The leading batch extent must divide by the mesh size — pad the batch
    (e.g. repeat a seed) or pass a smaller mesh otherwise.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_batch_mesh, shard_map_compat

    if mesh is None:
        mesh = make_batch_mesh()
    n_dev = mesh.devices.size
    init = make_init(cfg)
    roll = make_rollout(cfg, days)

    def one(p):
        return roll(p, init(p))

    # P("batch") as a prefix spec: shard the leading axis of every leaf
    mapped = shard_map_compat(jax.vmap(one), mesh=mesh,
                              in_specs=P("batch"), out_specs=P("batch"))
    mapped = jax.jit(mapped)

    def run(params: SimParams):
        b = jax.tree_util.tree_leaves(params)[0].shape[0]
        if b % n_dev:
            raise ValueError(
                f"batch of {b} rollouts does not divide across the "
                f"{n_dev}-device mesh; pad the (scenario x seed) batch or "
                "pass a smaller mesh")
        return mapped(params)

    return run


def rollout_sequential(cfg: SimConfig, days: int, params: SimParams,
                       state: SimState):
    """Debugging reference: drive the SAME jitted day step from a Python
    loop. Agrees with the scan engine to float tolerance (XLA may compile
    the standalone step with different FMA/fusion choices than the scan
    body); the bitwise guarantee is batched-vs-unbatched `make_rollout`."""
    step = stages.jitted_day_step(cfg.stage_config())
    ledger = init_ledger(cfg.n_clusters)
    for d in range(days):
        state, out = step(params, state, _day_xs(params, d))
        ledger = ledger_update(ledger, _metrics(out.res, out.cf))
    return state, ledger
