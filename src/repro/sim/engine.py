"""Batched fleet rollout engine.

The legacy day cycle in `core/fleet.py` steps a Python loop over a mutable
dataclass, so one fleet-day costs hundreds of eager dispatches and nothing
batches. This engine re-expresses the SAME pipeline (forecast -> optimize ->
shape -> observe -> SLO feedback, built from the pure array functions now
exposed by core/) as:

  * `SimState` / `SimParams` — flat pytrees of arrays only. No configs,
    no Python objects: everything a scenario perturbs is an array leaf.
  * `make_day_step(cfg)`   — one pure, jit-compiled CICS day.
  * `make_rollout(cfg, d)` — `lax.scan` of the day step, carrying an
    emissions ledger and advancing an UNSHAPED counterfactual fleet
    (identical arrivals, VCC = machine capacity) in the same trace.
  * `rollout_batch`        — `jax.vmap` of the rollout across a leading
    (scenario x seed) axis of stacked SimParams/SimState.

Parity contract (tested): a vmap'd batch reproduces each scenario's
non-batched sequential rollout BITWISE, for any batch size. This needs
batch-invariant numerics — ordered reductions for daily totals
(`admission.hour_sum`, `_hsum`), the elementwise `power._solve_spd` /
`power.pd_power`, and `optimization_barrier` materialization points at
stage boundaries so XLA cannot re-fuse (and re-round) a producer when its
consumers change. `rollout_sequential` additionally drives the same jitted
day step from a Python loop — a debugging reference that agrees to float
tolerance (standalone-vs-scan-body compilation may differ in FMA choices).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, carbon, fleet, power, slo, spatial, vcc
from repro.sim.ledger import DayMetrics, init_ledger, ledger_update

f32 = jnp.float32


def _register_barrier_batching():
    """jax<=0.4 ships no vmap rule for optimization_barrier (newer jax
    does). The rule is the identity on batch dims: barrier each operand,
    keep its batch axis."""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as _lax
        prim = _lax.optimization_barrier_p
    except (ImportError, AttributeError):    # pragma: no cover
        return
    if prim in batching.primitive_batchers:
        return

    def rule(args, dims):
        return prim.bind(*args), dims

    batching.primitive_batchers[prim] = rule


_register_barrier_batching()


@dataclass(frozen=True)
class SimConfig:
    """Static structure (shapes + solver knobs). Everything dynamic —
    prices, risk, weather, outages — lives in SimParams as arrays."""
    n_clusters: int = 16
    n_campuses: int = 4
    n_zones: int = 4
    pds_per_cluster: int = 2
    hist_days: int = 35           # rolling-history window (weeks * 7)
    slo_margin: float = 1.0
    slo_pause_days: int = 7
    spatial_iters: int = 100      # spatial pre-shift PGD iterations


class SimParams(NamedTuple):
    """Per-rollout scenario parameters. All leaves are arrays; stacking a
    list of SimParams along axis 0 gives the (scenario x seed) batch."""
    key: jnp.ndarray                  # PRNG key data, (2,) uint32
    truth: Dict[str, jnp.ndarray]     # latent cluster processes, (n,)
    pd_idle: jnp.ndarray              # (n*pds,)
    pd_slope: jnp.ndarray             # (n*pds,)
    pd_curve: jnp.ndarray             # (n*pds,)
    lam: jnp.ndarray                  # (n, pds) PD usage fractions
    zone: Dict[str, jnp.ndarray]      # grid-mix params, (z,)
    lambda_e: jnp.ndarray             # () carbon price
    lambda_p: jnp.ndarray             # () peak-power price
    gamma: jnp.ndarray                # () power-capping violation prob
    mobility: jnp.ndarray             # () spatial-shift mobility (0 = off)
    green_scale: jnp.ndarray          # (days, z) solar+wind multiplier
    coal_scale: jnp.ndarray           # (days, z) coal-share multiplier
    cap_scale: jnp.ndarray            # (days, n) capacity multiplier
    arrival_scale: jnp.ndarray        # (days, n) flexible-demand multiplier
    campus_scale: jnp.ndarray         # (days, m) campus power-limit scale


class SimState(NamedTuple):
    """Array-only rollout state (the scan carry)."""
    day: jnp.ndarray                  # () int32
    campus: jnp.ndarray               # (n,) int32
    zmap: jnp.ndarray                 # (n,) int32 zone of cluster
    campus_limit: jnp.ndarray         # (m,) kW
    u_pow_cap: jnp.ndarray            # (n,)
    hist_uif: jnp.ndarray             # (n, H, 24)
    hist_flex_daily: jnp.ndarray      # (n, H)
    hist_res_daily: jnp.ndarray       # (n, H)
    hist_usage: jnp.ndarray           # (n, H, 24)
    hist_res: jnp.ndarray             # (n, H, 24)
    hist_tr_pred: jnp.ndarray         # (n, H)
    hist_uif_pred: jnp.ndarray        # (n, H, 24)
    carbon_hist: jnp.ndarray          # (z, H, 24)
    queue: jnp.ndarray                # (n,) shaped-run backlog
    cf_queue: jnp.ndarray             # (n,) counterfactual backlog
    crowded_streak: jnp.ndarray       # (n,) int32
    pause_left: jnp.ndarray           # (n,) int32
    violation_days: jnp.ndarray       # (n,) int32
    observed_days: jnp.ndarray        # (n,) int32
    shaping_allowed: jnp.ndarray      # (n,) bool


def _pd_truth(params: SimParams) -> power.PDTruth:
    return power.PDTruth(idle_kw=params.pd_idle, slope_kw=params.pd_slope,
                         curve=params.pd_curve)


def _roll(hist, new):
    """Drop oldest day, append new. hist (n, H[, 24]); new (n[, 24])."""
    return jnp.concatenate([hist[:, 1:], new[:, None]], axis=1)


def _zone_day(params: SimParams, state: SimState, key, green_scale,
              coal_scale):
    """Draw one day of actual zone intensity + its day-ahead forecast."""
    z = state.carbon_hist.shape[0]
    zp = dict(params.zone)
    zp["solar_cap"] = zp["solar_cap"] * green_scale
    zp["wind_cap"] = zp["wind_cap"] * green_scale
    zp["coal_share"] = zp["coal_share"] * coal_scale
    keys = jax.random.split(key, 2 * z)
    act_z = carbon.simulate_zones_from(keys[:z], zp, 1)[:, 0]     # (z, 24)
    fc_z = jax.vmap(carbon.forecast_day_ahead)(
        keys[z:], state.carbon_hist, act_z, zp["weather_vol"] * 0.15)
    return act_z, fc_z


def _observe(params: SimParams, state: SimState, day_key,
             vcc_curve, cap_day, arr_scale, power_fn, intensity):
    """Sample the day's true load and run shaped + counterfactual
    admission. Returns (shaped DayResult, counterfactual DayResult,
    u_if, arrivals)."""
    u_if = fleet._sample_inflexible(jax.random.fold_in(day_key, 2),
                                    params.truth, state.day)
    u_if = jnp.minimum(u_if, 0.98 * cap_day[:, None])   # outage derates
    arrivals = fleet._sample_arrivals(jax.random.fold_in(day_key, 3),
                                      params.truth, state.day)
    arrivals = arrivals * arr_scale[:, None]
    ratio_true = fleet._true_ratio(params.truth, u_if + arrivals)
    # pin the sampled truth: its elementwise chain must not re-fuse (and
    # re-round) differently between the scan body and other contexts
    u_if, arrivals, ratio_true = jax.lax.optimization_barrier(
        (u_if, arrivals, ratio_true))
    res = admission.run_day(vcc_curve, u_if, arrivals, ratio_true, cap_day,
                            state.queue, power_fn, intensity)
    unshaped = jnp.broadcast_to(cap_day[:, None] * 10.0, vcc_curve.shape)
    cf = admission.run_day(unshaped, u_if, arrivals, ratio_true, cap_day,
                           state.cf_queue, power_fn, intensity)
    return _barrier_result(res), _barrier_result(cf), u_if, arrivals


# ordered sum over the last axis: the batch-invariant reduction primitive
# (single definition — the parity contract depends on these staying one op)
_hsum = admission.hour_sum


def _barrier_result(res: admission.DayResult) -> admission.DayResult:
    """Pin a DayResult as an XLA materialization point. Without it, XLA
    fuses admission outputs into downstream consumers, and the fusion plan
    (hence float rounding) shifts with batch extent — breaking bitwise
    batched-vs-sequential parity. Field order mirrors the dataclass."""
    vals = jax.lax.optimization_barrier(
        (res.usage_flex, res.usage_total, res.reservations, res.power,
         res.carbon, res.served, res.arrived, res.queue_end, res.unmet))
    return admission.DayResult(*vals)


def _metrics(res, cf) -> DayMetrics:
    return DayMetrics(
        carbon_kg=_hsum(res.carbon), kwh=_hsum(res.power),
        peak_kw=res.power.max(axis=-1), served=res.served,
        arrived=res.arrived, unmet=res.unmet, queue_end=res.queue_end,
        cf_carbon_kg=_hsum(cf.carbon), cf_kwh=_hsum(cf.power),
        cf_peak_kw=cf.power.max(axis=-1), cf_served=cf.served,
        cf_queue_end=cf.queue_end)


def make_day_step(cfg: SimConfig):
    """One pure CICS day: forecast -> optimize -> shape -> observe -> SLO.

    Returns step(params, state, xs) -> (state', DayMetrics) where xs holds
    this day's scenario-schedule slices."""
    slo_cfg = slo.SLOConfig(margin=cfg.slo_margin,
                            pause_days=cfg.slo_pause_days)

    def step(params: SimParams, state: SimState, xs: Dict[str, jnp.ndarray]
             ) -> Tuple[SimState, DayMetrics]:
        day_key = jax.random.fold_in(params.key, state.day)
        cap_day = jax.lax.optimization_barrier(
            params.truth["capacity"] * xs["cap_scale"])
        # 1-2. power pipeline + load forecasting on rolling history
        power_fn, slope_fn, _ = fleet.power_model_from_history(
            state.hist_usage, params.lam, params.truth["capacity"],
            _pd_truth(params), jax.random.fold_in(day_key, 1))
        fc = fleet.day_forecasts_arrays(
            state.hist_uif, state.hist_flex_daily, state.hist_res_daily,
            state.hist_usage, state.hist_res, state.hist_tr_pred,
            state.hist_uif_pred, state.day, params.gamma)
        fc = jax.lax.optimization_barrier(fc)
        # 3. carbon pipeline: scenario-perturbed grid, day-ahead forecast
        act_z, fc_z = jax.lax.optimization_barrier(_zone_day(
            params, state, jax.random.fold_in(day_key, 4),
            xs["green_scale"], xs["coal_scale"]))
        eta_act = act_z[state.zmap]
        eta_fc = fc_z[state.zmap]
        # 4. fleetwide risk-aware VCC optimization (+ optional spatial
        #    pre-shift; mobility == 0 collapses the shift to exactly zero)
        prob = fleet.build_problem_arrays(
            fc, eta_fc, power_fn, slope_fn, state.queue,
            state.u_pow_cap * xs["cap_scale"], cap_day, state.campus,
            state.campus_limit * xs["campus_scale"],
            params.lambda_e, params.lambda_p)
        prob = jax.lax.optimization_barrier(prob)
        tau_shifted, _ = spatial.spatial_shift(prob,
                                               mobility=params.mobility,
                                               iters=cfg.spatial_iters)
        tau_shifted = jax.lax.optimization_barrier(tau_shifted)
        prob = dataclasses.replace(prob, tau=tau_shifted)
        sol = vcc.solve_vcc(prob)
        # 5. SLO gate: paused clusters get VCC = machine capacity
        gate = state.shaping_allowed & sol.shaped
        vcc_curve = jnp.where(gate[:, None], sol.vcc, cap_day[:, None] * 10.0)
        vcc_curve = jax.lax.optimization_barrier(vcc_curve)
        # record predictions for trailing-error quantiles
        hist_tr_pred = _roll(state.hist_tr_pred, fc["tr"])
        hist_uif_pred = _roll(state.hist_uif_pred, fc["uif"])
        # 6. real time: admission on ACTUAL load (+ counterfactual)
        res, cf, u_if, _ = _observe(params, state, day_key, vcc_curve,
                                    cap_day, xs["arrival_scale"], power_fn,
                                    eta_act)
        # 7. telemetry + SLO feedback
        slo_state = {"crowded_streak": state.crowded_streak,
                     "pause_left": state.pause_left,
                     "violation_days": state.violation_days,
                     "observed_days": state.observed_days}
        new_slo, allowed = slo.update(slo_state, slo_cfg,
                                      _hsum(res.reservations),
                                      _hsum(vcc_curve), res.unmet)
        new_state = state._replace(
            day=state.day + 1,
            hist_uif=_roll(state.hist_uif, u_if),
            hist_flex_daily=_roll(state.hist_flex_daily, res.served),
            hist_res_daily=_roll(state.hist_res_daily,
                                 _hsum(res.reservations)),
            hist_usage=_roll(state.hist_usage, res.usage_total),
            hist_res=_roll(state.hist_res, res.reservations),
            hist_tr_pred=hist_tr_pred,
            hist_uif_pred=hist_uif_pred,
            carbon_hist=_roll(state.carbon_hist, act_z),
            queue=res.queue_end,
            cf_queue=cf.queue_end,
            crowded_streak=new_slo["crowded_streak"],
            pause_left=new_slo["pause_left"],
            violation_days=new_slo["violation_days"],
            observed_days=new_slo["observed_days"],
            shaping_allowed=allowed,
        )
        return new_state, _metrics(res, cf)

    return step


def _burnin_step(cfg: SimConfig, params: SimParams, state: SimState
                 ) -> SimState:
    """One unshaped day with the cheap linear power proxy (history fill)."""
    day_key = jax.random.fold_in(params.key, state.day)
    cap = params.truth["capacity"]

    def proxy_power(u):
        return 100.0 + 300.0 * u

    act_z, _ = _zone_day(params, state, jax.random.fold_in(day_key, 4),
                         jnp.ones_like(params.zone["solar_cap"]),
                         jnp.ones_like(params.zone["solar_cap"]))
    unshaped = jnp.broadcast_to(cap[:, None] * 10.0,
                                (cap.shape[0], 24))
    u_if = fleet._sample_inflexible(jax.random.fold_in(day_key, 2),
                                    params.truth, state.day)
    u_if = jnp.minimum(u_if, 0.98 * cap[:, None])
    arrivals = fleet._sample_arrivals(jax.random.fold_in(day_key, 3),
                                      params.truth, state.day)
    ratio_true = fleet._true_ratio(params.truth, u_if + arrivals)
    u_if, arrivals, ratio_true = jax.lax.optimization_barrier(
        (u_if, arrivals, ratio_true))
    res = admission.run_day(unshaped, u_if, arrivals, ratio_true, cap,
                            state.queue, proxy_power, act_z[state.zmap])
    res = _barrier_result(res)
    return state._replace(
        day=state.day + 1,
        hist_uif=_roll(state.hist_uif, u_if),
        hist_flex_daily=_roll(state.hist_flex_daily, res.served),
        hist_res_daily=_roll(state.hist_res_daily,
                             _hsum(res.reservations)),
        hist_usage=_roll(state.hist_usage, res.usage_total),
        hist_res=_roll(state.hist_res, res.reservations),
        carbon_hist=_roll(state.carbon_hist, act_z),
        queue=res.queue_end,
        cf_queue=res.queue_end,
    )


def make_init(cfg: SimConfig):
    """init(params) -> burned-in SimState. jit- and vmap-compatible."""
    n, m, z, H = (cfg.n_clusters, cfg.n_campuses, cfg.n_zones,
                  cfg.hist_days)
    campus_np = np.arange(n) % m
    zmap_np = (np.arange(m) % z)[campus_np]

    def init(params: SimParams) -> SimState:
        cap = params.truth["capacity"]
        state = SimState(
            day=jnp.zeros((), jnp.int32),
            campus=jnp.asarray(campus_np, jnp.int32),
            zmap=jnp.asarray(zmap_np, jnp.int32),
            campus_limit=jnp.zeros((m,), f32),
            u_pow_cap=cap * 0.95,
            hist_uif=jnp.zeros((n, H, 24), f32),
            hist_flex_daily=jnp.zeros((n, H), f32),
            hist_res_daily=jnp.zeros((n, H), f32),
            hist_usage=jnp.zeros((n, H, 24), f32),
            hist_res=jnp.zeros((n, H, 24), f32),
            hist_tr_pred=jnp.zeros((n, H), f32),
            hist_uif_pred=jnp.zeros((n, H, 24), f32),
            carbon_hist=jnp.zeros((z, H, 24), f32),
            queue=jnp.zeros((n,), f32),
            cf_queue=jnp.zeros((n,), f32),
            crowded_streak=jnp.zeros((n,), jnp.int32),
            pause_left=jnp.zeros((n,), jnp.int32),
            violation_days=jnp.zeros((n,), jnp.int32),
            observed_days=jnp.zeros((n,), jnp.int32),
            shaping_allowed=jnp.ones((n,), bool),
        )

        def burn(s, _):
            return _burnin_step(cfg, params, s), None

        state, _ = jax.lax.scan(burn, state, None, length=H)
        # zero-error prediction prior; honest quantiles build up in-horizon
        state = state._replace(hist_tr_pred=state.hist_res_daily,
                               hist_uif_pred=state.hist_uif)
        # campus contracts: 97% of fitted-model campus peak over last week
        power_fn, _, _ = fleet.power_model_from_history(
            state.hist_usage, params.lam, cap, _pd_truth(params),
            jax.random.fold_in(params.key, 999))
        upow = jax.vmap(power_fn, in_axes=1, out_axes=1)(
            state.hist_usage[:, -7:].reshape(n, -1))
        peak = upow.max(axis=1)
        limit = jax.ops.segment_sum(peak, state.campus,
                                    num_segments=m) * 0.97
        state = state._replace(campus_limit=limit.astype(f32))
        # materialize: burned-in state must not fuse into rollout consumers
        # (jit(init + rollout) would otherwise drift vs separate calls)
        return jax.lax.optimization_barrier(state)

    return init


def _day_xs(params: SimParams, d=None):
    """Scenario-schedule slices. With d=None returns scan xs (leading day
    axis); with an int d returns that single day's slices."""
    sched = {"green_scale": params.green_scale,
             "coal_scale": params.coal_scale,
             "cap_scale": params.cap_scale,
             "arrival_scale": params.arrival_scale,
             "campus_scale": params.campus_scale}
    if d is None:
        return sched
    return {k: v[d] for k, v in sched.items()}


def make_rollout(cfg: SimConfig, days: int):
    """rollout(params, state) -> (state', Ledger, traj dict of (days,))."""
    step = make_day_step(cfg)

    def rollout(params: SimParams, state: SimState):
        horizon = params.cap_scale.shape[-2]
        if horizon < days:
            raise ValueError(
                f"params schedules cover {horizon} days but the rollout "
                f"asks for {days}; rebuild with build_params(..., "
                f"days>={days})")
        ledger = init_ledger(cfg.n_clusters)

        def body(carry, xs):
            s, led = carry
            s, metrics = step(params, s, xs)
            led = ledger_update(led, metrics)
            traj = {"carbon_kg": _hsum(metrics.carbon_kg),
                    "cf_carbon_kg": _hsum(metrics.cf_carbon_kg),
                    "kwh": _hsum(metrics.kwh),
                    "peak_kw": _hsum(metrics.peak_kw),
                    "queue": _hsum(metrics.queue_end)}
            return (s, led), traj

        xs = jax.tree.map(lambda a: a[:days], _day_xs(params))
        (state, ledger), traj = jax.lax.scan(body, (state, ledger), xs)
        return state, ledger, traj

    return rollout


def rollout_batch(cfg: SimConfig, days: int):
    """vmap'd (init + rollout) over a leading (scenario x seed) axis."""
    init = make_init(cfg)
    roll = make_rollout(cfg, days)

    @jax.jit
    def run(params: SimParams):
        def one(p):
            return roll(p, init(p))
        return jax.vmap(one)(params)

    return run


def rollout_sequential(cfg: SimConfig, days: int, params: SimParams,
                       state: SimState):
    """Debugging reference: drive the SAME jitted day step from a Python
    loop. Agrees with the scan engine to float tolerance (XLA may compile
    the standalone step with different FMA/fusion choices than the scan
    body); the bitwise guarantee is batched-vs-unbatched `make_rollout`."""
    step = jax.jit(make_day_step(cfg))
    ledger = init_ledger(cfg.n_clusters)
    for d in range(days):
        state, metrics = step(params, state, _day_xs(params, d))
        ledger = ledger_update(ledger, metrics)
    return state, ledger
