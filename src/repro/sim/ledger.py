"""Emissions ledger (codecarbon/RackMind-inspired) for batched rollouts.

Accumulates per-cluster cumulative kgCO2e, kWh, peak power, delayed
CPU-hours and flexible-work completion for the shaped run AND the unshaped
counterfactual that the engine advances in the same batch. A Ledger is a
flat pytree of arrays, so it rides in the `lax.scan` carry and vmaps across
the (scenario x seed) axis for free.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax.numpy as jnp

f32 = jnp.float32


class DayMetrics(NamedTuple):
    """Per-cluster reductions of one simulated day (all (n,))."""
    carbon_kg: jnp.ndarray        # sum_h power * intensity
    kwh: jnp.ndarray              # sum_h power (kW over 1h ticks)
    peak_kw: jnp.ndarray          # max_h power
    served: jnp.ndarray           # flexible CPU-h served
    arrived: jnp.ndarray          # flexible CPU-h arrived
    unmet: jnp.ndarray            # SLO-relevant backlog growth
    queue_end: jnp.ndarray        # flexible CPU-h carried overnight
    cf_carbon_kg: jnp.ndarray     # unshaped counterfactual, same day
    cf_kwh: jnp.ndarray
    cf_peak_kw: jnp.ndarray
    cf_served: jnp.ndarray
    cf_queue_end: jnp.ndarray


class Ledger(NamedTuple):
    """Cumulative per-cluster totals over a rollout (all (n,) but days)."""
    days: jnp.ndarray             # () f32 day counter
    carbon_kg: jnp.ndarray
    kwh: jnp.ndarray
    peak_kw: jnp.ndarray          # running max over days
    served: jnp.ndarray
    arrived: jnp.ndarray
    unmet: jnp.ndarray
    delayed_cpu_h: jnp.ndarray    # sum of nightly carried queue
    cf_carbon_kg: jnp.ndarray
    cf_kwh: jnp.ndarray
    cf_peak_kw: jnp.ndarray
    cf_served: jnp.ndarray
    cf_delayed_cpu_h: jnp.ndarray


def init_ledger(n_clusters: int) -> Ledger:
    z = jnp.zeros((n_clusters,), f32)
    return Ledger(days=jnp.zeros((), f32), carbon_kg=z, kwh=z, peak_kw=z,
                  served=z, arrived=z, unmet=z, delayed_cpu_h=z,
                  cf_carbon_kg=z, cf_kwh=z, cf_peak_kw=z, cf_served=z,
                  cf_delayed_cpu_h=z)


def ledger_update(led: Ledger, m: DayMetrics) -> Ledger:
    return Ledger(
        days=led.days + 1.0,
        carbon_kg=led.carbon_kg + m.carbon_kg,
        kwh=led.kwh + m.kwh,
        peak_kw=jnp.maximum(led.peak_kw, m.peak_kw),
        served=led.served + m.served,
        arrived=led.arrived + m.arrived,
        unmet=led.unmet + m.unmet,
        delayed_cpu_h=led.delayed_cpu_h + m.queue_end,
        cf_carbon_kg=led.cf_carbon_kg + m.cf_carbon_kg,
        cf_kwh=led.cf_kwh + m.cf_kwh,
        cf_peak_kw=jnp.maximum(led.cf_peak_kw, m.cf_peak_kw),
        cf_served=led.cf_served + m.cf_served,
        cf_delayed_cpu_h=led.cf_delayed_cpu_h + m.cf_queue_end,
    )


def summarize(led: Ledger, initial_backlog=0.0) -> Dict[str, jnp.ndarray]:
    """Fleet-level scalars for one rollout; vmap for batched ledgers.

    ``initial_backlog``: fleet-total flexible CPU-h queued when the
    rollout started (sum of the burned-in SimState ``queue``). Served
    work can legitimately exceed in-horizon arrivals when that backlog
    drains, so completion is reported as served-of-(arrived + initial
    backlog) — a true fraction, clipped to 100%."""
    carbon = led.carbon_kg.sum()
    cf_carbon = jnp.clip(led.cf_carbon_kg.sum(), 1e-9, None)
    kwh = led.kwh.sum()
    cf_kwh = jnp.clip(led.cf_kwh.sum(), 1e-9, None)
    peak = led.peak_kw.sum()                 # sum of per-cluster peaks
    cf_peak = jnp.clip(led.cf_peak_kw.sum(), 1e-9, None)
    arrived = jnp.clip(led.arrived.sum(), 1e-9, None)
    return {
        "carbon_kg": carbon,
        "cf_carbon_kg": cf_carbon,
        "carbon_saved_pct": 100.0 * (cf_carbon - carbon) / cf_carbon,
        "kwh": kwh,
        "kwh_saved_pct": 100.0 * (cf_kwh - kwh) / cf_kwh,
        "peak_kw": peak,
        "peak_reduction_pct": 100.0 * (cf_peak - peak) / cf_peak,
        "flex_within_24h_pct": 100.0 * (1.0 - jnp.clip(
            led.unmet.sum() / arrived, 0.0, 1.0)),
        "flex_completion_pct": 100.0 * jnp.clip(
            led.served.sum() / (arrived + initial_backlog), 0.0, 1.0),
        "delayed_cpu_h_per_day": led.delayed_cpu_h.sum()
        / jnp.clip(led.days, 1.0, None),
        "mean_intensity_kg_per_kwh": carbon / jnp.clip(kwh, 1e-9, None),
    }
