"""Reduce batched rollouts into per-scenario summary tables.

Consumed by benchmarks (BENCH_sim.json rows) and examples/scenario_sweep.py.
Input: a batched Ledger whose leading axis is scenario-major x seed-minor
(the layout produced by scenarios.build_batch).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import stats
from repro.sim.ledger import Ledger, summarize

COLUMNS = ("carbon_saved_pct", "peak_reduction_pct", "flex_within_24h_pct",
           "kwh_saved_pct", "delayed_cpu_h_per_day")


def state_nbytes(state, batch: int = 1) -> int:
    """Per-rollout bytes of a carried state pytree (SimState — streaming
    or rescan). ``batch``: leading (scenario x seed) extent to divide
    out when the state came from a batched rollout."""
    return stats.pytree_nbytes(state) // max(batch, 1)


def scenario_rows(ledgers: Ledger, scenario_names: Sequence[str],
                  n_seeds: int, horizon_days: Optional[int] = None,
                  state_bytes: Optional[int] = None,
                  initial_backlog=None,
                  slo_allowance: Optional[float] = None
                  ) -> List[Dict[str, float]]:
    """Per-scenario mean +/- std (over seeds) of the ledger summaries.

    ``horizon_days`` (rollout length) and ``state_bytes`` (per-rollout
    carried state size, see ``state_nbytes``) tag every row when given,
    so sweeps record the memory footprint alongside throughput — the
    axis the streaming prediction layer moves. ``initial_backlog``: (B,)
    fleet-total queue at rollout start, threaded into
    ``ledger.summarize`` so flex_completion_pct stays a true fraction
    when a burned-in backlog drains. ``slo_allowance``: the
    late-arrival allowance fraction the rollouts' unmet accounting used
    (SimConfig.slo_allowance), tagged on every row so the table records
    what the SLO gate was measured against."""
    if initial_backlog is None:
        summaries = jax.vmap(summarize)(ledgers)    # dict of (B,)
    else:
        summaries = jax.vmap(summarize)(ledgers, initial_backlog)
    rows = []
    for i, name in enumerate(scenario_names):
        sl = slice(i * n_seeds, (i + 1) * n_seeds)
        row: Dict[str, float] = {"scenario": name, "n_seeds": n_seeds}
        if horizon_days is not None:
            row["horizon_days"] = int(horizon_days)
        if state_bytes is not None:
            row["state_bytes"] = int(state_bytes)
        if slo_allowance is not None:
            row["slo_allowance"] = float(slo_allowance)
        for k, v in summaries.items():
            vals = np.asarray(v[sl], dtype=np.float64)
            row[k] = float(vals.mean())
            # seeds are a SAMPLE of the scenario's rollout distribution:
            # Bessel-corrected std (ddof=1); a single seed pins 0.0 (an
            # n=1 sample has no spread estimate), never NaN
            row[k + "_std"] = float(vals.std(ddof=1)) if n_seeds > 1 else 0.0
        rows.append(row)
    return rows


RISK_COLUMNS = ("carbon_saved_pct", "flex_completion_pct",
                "flex_within_24h_pct", "delayed_cpu_h_per_day")


MOBILITY_COLUMNS = ("carbon_saved_pct", "carbon_vs_sequential_pct",
                    "peak_reduction_pct", "flex_within_24h_pct")


TELEMETRY_COLUMNS = ("obj_decrease_pct", "uif_mape", "theta_coverage",
                     "uifq_coverage", "vcc_binding_frac", "queue_age_max")


def telemetry_rows(records, scenario_names: Optional[Sequence[str]] = None
                   ) -> List[Dict[str, float]]:
    """Per-scenario mean +/- std of the telemetry trace records
    (``telemetry.telemetry_records`` — one record per scenario x seed x
    day). The std pools seeds AND days (sample std, ddof=1 when more
    than one record contributes; a single record pins 0.0). Rows render
    with ``format_table(rows, TELEMETRY_COLUMNS)``."""
    by_scen: Dict[str, List[dict]] = {}
    for r in records:
        by_scen.setdefault(r["scenario"], []).append(r)
    names = scenario_names if scenario_names is not None else by_scen
    rows: List[Dict[str, float]] = []
    for name in names:
        rs = by_scen.get(name, [])
        if not rs:
            continue
        keys = [k for k in rs[0] if k not in ("scenario", "seed", "day")]
        row: Dict[str, float] = {"scenario": name, "n_records": len(rs)}
        for k in keys:
            vals = np.asarray([r[k] for r in rs], dtype=np.float64)
            row[k] = float(vals.mean())
            row[k + "_std"] = \
                float(vals.std(ddof=1)) if len(rs) > 1 else 0.0
        rows.append(row)
    return rows


def mobility_sweep_rows(led_joint: Ledger, led_seq: Ledger,
                        scenario_names: Sequence[str], n_seeds: int
                        ) -> List[Dict[str, float]]:
    """Rows for the mobility sweep: ledger summaries of the JOINT
    (``SimConfig(joint_spatial=True)``) rollouts plus the carbon delta
    against the sequential pre-shift rollouts of the SAME
    (scenario x seed) batch. ``carbon_vs_sequential_pct > 0`` means the
    joint optimizer emitted less than the decoupled greedy pre-shift +
    temporal solve."""
    rows = scenario_rows(led_joint, scenario_names, n_seeds)
    seq = scenario_rows(led_seq, scenario_names, n_seeds)
    for r, q in zip(rows, seq):
        base = max(abs(q["carbon_kg"]), 1e-9)
        r["carbon_vs_sequential_pct"] = \
            100.0 * (q["carbon_kg"] - r["carbon_kg"]) / base
        r["sequential_carbon_kg"] = q["carbon_kg"]
    return rows


MPC_COLUMNS = ("carbon_saved_pct", "carbon_vs_open_pct",
               "flex_within_24h_pct", "flex24h_vs_open_pp",
               "delayed_cpu_h_per_day")


def mpc_recourse_rows(led_mpc: Ledger, led_open: Ledger,
                      scenario_names: Sequence[str], n_seeds: int
                      ) -> List[Dict[str, float]]:
    """Rows for the intra-day recourse comparison: ledger summaries of
    the CLOSED-loop (``SimConfig(mpc=True)``) rollouts plus deltas
    against the open-loop rollouts of the SAME (scenario x seed) batch.
    ``carbon_vs_open_pct > 0`` means hourly recourse emitted less carbon
    than committing to the 00:00 plan; ``flex24h_vs_open_pp`` is the
    within-24h flex service improvement in percentage points. The MPC
    acceptance gate (benchmarks/sim_bench.py) requires every
    forecast-busting row to improve on at least one of the two."""
    rows = scenario_rows(led_mpc, scenario_names, n_seeds)
    open_rows = scenario_rows(led_open, scenario_names, n_seeds)
    for r, q in zip(rows, open_rows):
        base = max(abs(q["carbon_kg"]), 1e-9)
        r["carbon_vs_open_pct"] = \
            100.0 * (q["carbon_kg"] - r["carbon_kg"]) / base
        r["flex24h_vs_open_pp"] = \
            r["flex_within_24h_pct"] - q["flex_within_24h_pct"]
        r["open_carbon_kg"] = q["carbon_kg"]
        r["open_flex_within_24h_pct"] = q["flex_within_24h_pct"]
    return rows


def risk_sweep_rows(ledgers_by_k: Dict[int, "Ledger"],
                    scenario_names: Sequence[str], n_seeds: int
                    ) -> List[Dict[str, float]]:
    """Flatten a {n_members: batched Ledger} sweep (one batch per ensemble
    size K, each batch = the risk_sweep_library beta axis x seeds) into
    rows tagged with an ``n_members`` field — the carbon vs
    flex-completion risk trade-off data, consumed by both the bench JSON
    and the example table. Data only: prefix ``scenario`` with the K for
    display (see examples/scenario_sweep.py) before ``format_table(rows,
    RISK_COLUMNS)``."""
    rows: List[Dict[str, float]] = []
    for k, led in sorted(ledgers_by_k.items()):
        for r in scenario_rows(led, scenario_names, n_seeds):
            r["n_members"] = k
            rows.append(r)
    return rows


def format_table(rows: List[Dict[str, float]],
                 columns: Sequence[str] = COLUMNS) -> str:
    """Fixed-width ASCII table: one line per scenario."""
    name_w = max([len("scenario")] + [len(r["scenario"]) for r in rows]) + 2
    headers = {"carbon_saved_pct": "carbonSaved%",
               "carbon_vs_sequential_pct": "vsSeq%",
               "carbon_vs_open_pct": "vsOpen%",
               "flex24h_vs_open_pp": "flex24hΔpp",
               "peak_reduction_pct": "peakRed%",
               "flex_within_24h_pct": "flex<24h%",
               "flex_completion_pct": "flexDone%",
               "kwh_saved_pct": "kwhSaved%",
               "delayed_cpu_h_per_day": "delayedCPUh/d",
               "obj_decrease_pct": "objDec%",
               "uif_mape": "uifMAPE",
               "theta_coverage": "thetaCov",
               "uifq_coverage": "uifQCov",
               "vcc_binding_frac": "vccBind",
               "queue_age_max": "queueAge"}
    cols = [headers.get(c, c) for c in columns]
    widths = [max(len(c), 12) for c in cols]
    out = ["scenario".ljust(name_w)
           + "  ".join(c.rjust(w) for c, w in zip(cols, widths))]
    out.append("-" * (name_w + sum(widths) + 2 * (len(cols) - 1)))
    for r in rows:
        cells = []
        for c, w in zip(columns, widths):
            std = r.get(c + "_std", 0.0)
            cells.append(f"{r[c]:+.2f}±{std:.2f}".rjust(w))
        out.append(r["scenario"].ljust(name_w) + "  ".join(cells))
    return "\n".join(out)
