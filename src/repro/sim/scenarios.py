"""Declarative scenario perturbations composable onto the synthetic fleet.

A Scenario = a name + scalar overrides (carbon price, risk, mobility) + a
tuple of Perturbation objects, each of which edits the multiplier
*schedules* (numpy arrays, one row per rollout day) that the engine
consumes. Composition is pure: `build_params(cfg, scenario, seed, days)`
always returns the identical SimParams pytree for identical inputs —
per-scenario randomness (e.g. which clusters an outage hits) is drawn from
a generator keyed on (seed, crc32(scenario.name)).

Scenario axes follow the related literature: renewable droughts and grid
mix shifts ("Let's Wait Awhile"), price/risk sweeps ("The War of the
Efficiencies"), plus operational events (outages, campus derates, demand
surges) from the paper's production narrative.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stages
from repro.sim.engine import SimConfig, SimParams

f32 = jnp.float32


# ------------------------------------------------------------ perturbations

@dataclass(frozen=True)
class Perturbation:
    """Base: edits the schedule dict in place. start/length in rollout
    days; length < 0 means 'until the end of the horizon'."""
    start: int = 0
    length: int = -1

    def window(self, days: int) -> slice:
        end = days if self.length < 0 else min(self.start + self.length,
                                               days)
        return slice(min(self.start, days), end)

    def apply(self, sched: Dict[str, np.ndarray], rng: np.random.Generator,
              cfg: SimConfig) -> None:
        raise NotImplementedError


@dataclass(frozen=True)
class RenewableDrought(Perturbation):
    """Dunkelflaute: solar+wind capacity drops by `depth` in some zones."""
    depth: float = 0.7
    zones: Optional[Tuple[int, ...]] = None      # None = all zones

    def apply(self, sched, rng, cfg):
        w = self.window(sched["green_scale"].shape[0])
        zs = list(self.zones) if self.zones is not None \
            else list(range(cfg.n_zones))
        sched["green_scale"][w, zs] *= (1.0 - self.depth)


@dataclass(frozen=True)
class CoalRetirement(Perturbation):
    """Linear ramp-down of the thermal coal share, `rate` per week."""
    rate_per_week: float = 0.05

    def apply(self, sched, rng, cfg):
        days = sched["coal_scale"].shape[0]
        w = self.window(days)
        t = np.arange(w.stop - w.start, dtype=np.float64)
        ramp = np.clip(1.0 - self.rate_per_week * t / 7.0, 0.0, None)
        sched["coal_scale"][w] *= ramp[:, None]


@dataclass(frozen=True)
class ClusterOutage(Perturbation):
    """A fraction of clusters loses most capacity for a window."""
    frac: float = 0.25
    derate: float = 0.1          # remaining capacity fraction

    def apply(self, sched, rng, cfg):
        w = self.window(sched["cap_scale"].shape[0])
        k = max(1, int(round(self.frac * cfg.n_clusters)))
        hit = np.sort(rng.choice(cfg.n_clusters, size=k, replace=False))
        sched["cap_scale"][w, hit] *= self.derate


@dataclass(frozen=True)
class CampusDerate(Perturbation):
    """Contracted campus power limit drops (grid event / demand response)."""
    scale: float = 0.85
    campuses: Optional[Tuple[int, ...]] = None

    def apply(self, sched, rng, cfg):
        w = self.window(sched["campus_scale"].shape[0])
        cs = list(self.campuses) if self.campuses is not None \
            else list(range(cfg.n_campuses))
        sched["campus_scale"][w, cs] *= self.scale


@dataclass(frozen=True)
class DemandSurge(Perturbation):
    """Flexible-demand arrivals scale up fleetwide for a window."""
    scale: float = 1.5

    def apply(self, sched, rng, cfg):
        w = self.window(sched["arrival_scale"].shape[0])
        sched["arrival_scale"][w] *= self.scale


@dataclass(frozen=True)
class CapacitySqueeze(Perturbation):
    """Fleetwide machine-capacity derate (tight-supply regime: temporal
    shaping bounds bind, so spatially exporting work matters)."""
    scale: float = 0.75

    def apply(self, sched, rng, cfg):
        w = self.window(sched["cap_scale"].shape[0])
        sched["cap_scale"][w] *= self.scale


def _hour_channel(sched: Dict[str, np.ndarray], key: str,
                  days: int) -> np.ndarray:
    """Lazily materialize an intraday (days, 24) multiplier channel. Kept
    out of the base schedule so scenarios without intraday perturbations
    build SimParams with the channel leaves = None (byte-identical
    compiled day-step graph — stages.SimParams)."""
    if key not in sched:
        sched[key] = np.ones((days, 24))
    return sched[key]


@dataclass(frozen=True)
class IntradayCarbonSpike(Perturbation):
    """Forecast-busting intra-day carbon spike: the ACTUAL zone intensity
    is scaled by ``scale`` for a contiguous ``hour_len``-hour block each
    day of the window, applied after the day-ahead forecast is drawn — the
    planner never sees it coming. ``hour_start=None`` randomizes the block
    per day (scenario rng), so the persistence-based carbon forecaster
    cannot lock onto a recurring pattern across days."""
    scale: float = 1.8
    hour_len: int = 8
    hour_start: Optional[int] = None

    def apply(self, sched, rng, cfg):
        days = sched["cap_scale"].shape[0]
        ch = _hour_channel(sched, "carbon_hour_scale", days)
        w = self.window(days)
        for d in range(w.start, w.stop):
            h0 = self.hour_start if self.hour_start is not None \
                else int(rng.integers(5, 24 - self.hour_len))
            ch[d, h0:min(h0 + self.hour_len, 24)] *= self.scale


@dataclass(frozen=True)
class IntradayDemandSurge(Perturbation):
    """Forecast-busting intra-day arrival surge: ACTUAL flexible arrivals
    scale by ``scale`` for a ``hour_len``-hour block each day of the
    window (random block per day when ``hour_start=None``). The load
    forecasters saw none of it when the day's tau was budgeted."""
    scale: float = 1.7
    hour_len: int = 6
    hour_start: Optional[int] = None

    def apply(self, sched, rng, cfg):
        days = sched["cap_scale"].shape[0]
        ch = _hour_channel(sched, "arrival_hour_scale", days)
        w = self.window(days)
        for d in range(w.start, w.stop):
            h0 = self.hour_start if self.hour_start is not None \
                else int(rng.integers(5, 24 - self.hour_len))
            ch[d, h0:min(h0 + self.hour_len, 24)] *= self.scale


# ----------------------------------------------------------------- scenario

@dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    perturbations: Tuple[Perturbation, ...] = ()
    lambda_e: float = 0.5        # carbon price (paper-style sweep axis)
    lambda_p: float = 0.05
    gamma: float = 0.05          # power-capping violation probability
    mobility: float = 0.0        # spatial-shift mobility (0 = paper mode)
    risk_beta: float = 1.0       # CVaR tail fraction (1.0 = risk-neutral;
    #                              only acts when SimConfig.n_members > 1)


def _scenario_rng(scenario: Scenario, seed: int) -> np.random.Generator:
    tag = zlib.crc32(scenario.name.encode("utf-8"))
    return np.random.default_rng((int(seed) << 32) ^ tag)


def build_params(cfg: SimConfig, scenario: Scenario, seed: int, days: int
                 ) -> SimParams:
    """Compose a scenario onto the synthetic fleet -> array-only SimParams.

    Pure: identical (cfg, scenario, seed, days) -> identical arrays.
    """
    n, m, z = cfg.n_clusters, cfg.n_campuses, cfg.n_zones
    # the same synthesis leaves the legacy fleet path uses (stage core)
    sp = stages.synth_params(seed, n, cfg.pds_per_cluster, z)

    sched = {
        "green_scale": np.ones((days, z)),
        "coal_scale": np.ones((days, z)),
        "cap_scale": np.ones((days, n)),
        "arrival_scale": np.ones((days, n)),
        "campus_scale": np.ones((days, m)),
    }
    rng = _scenario_rng(scenario, seed)
    for p in scenario.perturbations:
        p.apply(sched, rng, cfg)

    return SimParams(
        key=sp["key"],
        truth=sp["truth"], pd_idle=sp["pd_idle"], pd_slope=sp["pd_slope"],
        pd_curve=sp["pd_curve"], lam=sp["lam"], zone=sp["zone"],
        lambda_e=jnp.asarray(scenario.lambda_e, f32),
        lambda_p=jnp.asarray(scenario.lambda_p, f32),
        gamma=jnp.asarray(scenario.gamma, f32),
        mobility=jnp.asarray(scenario.mobility, f32),
        risk_beta=jnp.asarray(scenario.risk_beta, f32),
        green_scale=jnp.asarray(sched["green_scale"], f32),
        coal_scale=jnp.asarray(sched["coal_scale"], f32),
        cap_scale=jnp.asarray(sched["cap_scale"], f32),
        arrival_scale=jnp.asarray(sched["arrival_scale"], f32),
        campus_scale=jnp.asarray(sched["campus_scale"], f32),
        arrival_hour_scale=(
            jnp.asarray(sched["arrival_hour_scale"], f32)
            if "arrival_hour_scale" in sched else None),
        carbon_hour_scale=(
            jnp.asarray(sched["carbon_hour_scale"], f32)
            if "carbon_hour_scale" in sched else None),
    )


def build_batch(cfg: SimConfig, scenarios: Sequence[Scenario],
                seeds: Sequence[int], days: int) -> SimParams:
    """Stack (scenario x seed) SimParams along a new leading axis, scenario
    major: batch index b = i_scenario * len(seeds) + i_seed.

    Stacking needs a homogeneous pytree: if ANY rollout carries an
    intraday hour channel, the rollouts without it get the neutral
    all-ones channel (multiplying actuals by exactly 1.0 — identical
    results; an all-None column stays None and the batch keeps the
    channel-free graph)."""
    all_params = [build_params(cfg, sc, seed, days)
                  for sc in scenarios for seed in seeds]
    ones = jnp.ones((days, 24), f32)
    for field in ("arrival_hour_scale", "carbon_hour_scale"):
        if any(getattr(p, field) is not None for p in all_params):
            all_params = [
                p._replace(**{field: ones}) if getattr(p, field) is None
                else p for p in all_params]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *all_params)


# ------------------------------------------------------------------ library

def default_library(days: int = 14) -> List[Scenario]:
    """The standing scenario sweep (>= 8 scenarios)."""
    half = max(days // 2, 1)
    return [
        Scenario("baseline",
                 "nominal grid, nominal fleet"),
        Scenario("renewable_drought",
                 "70% solar+wind drop across all zones, second half",
                 (RenewableDrought(start=half, depth=0.7),)),
        Scenario("coal_retirement",
                 "coal share ramps down 10%/week from day 0",
                 (CoalRetirement(rate_per_week=0.10),)),
        Scenario("cluster_outage",
                 "25% of clusters derated to 10% capacity mid-horizon",
                 (ClusterOutage(start=half, length=max(days // 4, 1),
                                frac=0.25),)),
        Scenario("campus_derate",
                 "all campus power contracts cut 15%",
                 (CampusDerate(scale=0.85),)),
        Scenario("demand_surge",
                 "flexible arrivals x1.6 in the second half",
                 (DemandSurge(start=half, scale=1.6),)),
        Scenario("high_carbon_price",
                 "lambda_e x4: aggressive shaping",
                 lambda_e=2.0),
        Scenario("low_risk_tolerance",
                 "gamma 0.01: conservative power capping",
                 gamma=0.01),
        Scenario("spatial_mobility",
                 "30% of flexible work location-flexible (beyond-paper)",
                 mobility=0.3),
        Scenario("peak_shaver",
                 "peak-power-optimal pricing (lambda_p >> lambda_e): the "
                 "'War of the Efficiencies' counterpoint",
                 lambda_e=0.02, lambda_p=0.5),
        Scenario("perfect_storm",
                 "drought + outage + surge, compounded",
                 (RenewableDrought(start=half, depth=0.6),
                  ClusterOutage(start=half, length=max(days // 4, 1),
                                frac=0.2),
                  DemandSurge(start=half, scale=1.4))),
    ]


MOBILITY_SWEEP = (0.0, 0.1, 0.3, 0.6)


def mobility_sweep_library(days: int = 14,
                           mobilities: Sequence[float] = MOBILITY_SWEEP
                           ) -> List[Scenario]:
    """The spatial-mobility sweep family (joint spatio-temporal path).

    Mobility is swept as a data leaf (one batched rollout) under a
    geographically skewed, supply-tight grid: a deep renewable drought
    pinned to zone 0 for the whole horizon, a fleetwide demand surge, and
    a capacity squeeze — so the dirty zone's clusters saturate their
    shaping bounds and EXPORTING work (not just delaying it) is what
    saves carbon; this is the regime where the joint optimizer can beat
    the greedy pre-shift. mobility=0 is the temporal-only control row
    (the shift is pinned to zero; the joint path may still refine delta,
    so its realized rollouts match the sequential path only to float
    tolerance). Run with ``SimConfig(joint_spatial=True)`` and compare
    against the same batch under ``joint_spatial=False`` for the
    joint-vs-sequential carbon delta (``report.mobility_sweep_rows``,
    ``benchmarks/sim_bench.py``).
    """
    return [
        Scenario(f"mobility{int(round(100 * m)):03d}",
                 f"{m:.0%} of flexible work location-flexible under a "
                 "zone-0 drought + surge + capacity squeeze",
                 (RenewableDrought(depth=0.8, zones=(0,)),
                  DemandSurge(scale=1.3),
                  CapacitySqueeze(scale=0.75)),
                 lambda_e=1.0, lambda_p=0.02, mobility=m)
        for m in mobilities
    ]


def forecast_bust_library(days: int = 6) -> List[Scenario]:
    """Forecast-busting scenarios for the intra-day MPC recourse gate
    (``SimConfig.mpc``): the day-ahead plan is issued against clean
    forecasts, then the ACTUAL intensity / arrivals are hit by
    randomly-placed intra-day blocks the planner never saw. These are the
    rows where the closed loop must beat (or match) the open loop on
    carbon or unmet-flex — ``report.mpc_recourse_rows`` /
    ``benchmarks/sim_bench.py`` gate on every row."""
    return [
        Scenario("intraday_carbon_spike",
                 "unforecasted x1.8 intensity block, 8h/day, random hours",
                 (IntradayCarbonSpike(scale=1.8, hour_len=8),),
                 lambda_e=1.0),
        Scenario("intraday_demand_surge",
                 "unforecasted x1.7 arrival block, 6h/day, random hours",
                 (IntradayDemandSurge(scale=1.7, hour_len=6),),
                 lambda_e=1.0),
        Scenario("intraday_perfect_storm",
                 "carbon spike + arrival surge, independently placed",
                 (IntradayCarbonSpike(scale=1.6, hour_len=8),
                  IntradayDemandSurge(scale=1.5, hour_len=6)),
                 lambda_e=1.0),
    ]


RISK_BETAS = (0.5, 0.9, 0.99)
RISK_MEMBERS = (1, 8, 32)


def risk_sweep_library(days: int = 14,
                       betas: Sequence[float] = RISK_BETAS
                       ) -> List[Scenario]:
    """The risk-sweep scenario family: CVaR tail fraction beta swept under
    a forecast-hostile backdrop (drought + demand surge — the regimes
    'Let's Wait Awhile' shows are most forecast-error sensitive).

    beta is a data leaf, so the whole sweep batches in ONE rollout; the
    ensemble size K is a static shape, so pair this library with
    ``SimConfig(n_members=K)`` for each K in ``RISK_MEMBERS`` (K=1 makes
    every beta collapse to the identical point-forecast path — the
    degenerate control row).
    """
    half = max(days // 2, 1)
    backdrop = (RenewableDrought(start=half, depth=0.6),
                DemandSurge(start=half, scale=1.4))
    return [
        Scenario(f"risk_beta{int(round(100 * b)):02d}",
                 f"CVaR beta={b}: optimize the worst {b:.0%} of forecast "
                 "members under drought + surge",
                 backdrop, lambda_e=1.0, risk_beta=b)
        for b in betas
    ]
