"""Fleet telemetry layer: in-scan diagnostics + host-side trace export.

The measurement plane of the reproduction — three surfaces:

* **In-graph** (`DayTelemetry`, `day_telemetry`): a pytree record built
  inside the jitted day step when ``StageConfig.telemetry=True``. Solver
  convergence channels come from ``core.vcc.solve_vcc(telemetry=True)``
  (PGD objective/step trajectories through the dual-ascent scan,
  conservation/dual residuals, certified bisection tolerance, CVaR tail
  mass, joint-vs-sequential winner); forecast calibration (MAPE / bias /
  coverage of the day-ahead U_IF, T_UF, T_R and Theta forecasts against
  the realized day, plus a streaming-vs-rescan drift gauge against the
  trailing week) and SLO/headroom gauges (hourly VCC binding fraction,
  queue age) are computed here from the observe/SLO stage products. Every
  channel uses elementwise ops + ordered trailing-axis reductions
  (``admission.hour_sum``) and keeps the cluster axis unreduced, so the
  record rides ``lax.scan`` / ``vmap`` / ``shard_map`` without breaking
  the engine's bitwise batched==sequential parity contract. With the flag
  off the StepOut leaf stays ``None`` (an EMPTY pytree subtree): the
  legacy compiled graph is byte-identical (HLO-tested collapse contract).

* **Trace export** (`telemetry_records`, `write_jsonl`, `read_jsonl`):
  flatten a batched rollout's stacked DayTelemetry into one JSON record
  per scenario x seed x day (cluster axes reduced host-side), the schema
  consumed by ``report.telemetry_rows`` and the CI trace artifact.

* **Stage cost attribution** (`profile_stages`, `format_stage_table`):
  host-side profiler that compiles each stage standalone, reads static
  compiled cost from the HLO text (``launch.hlo_analysis.analyze_hlo``)
  and attributes wall-clock (best-of-reps, ``block_until_ready``) per
  stage against the full jitted day step.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stages
from repro.core.admission import hour_sum

f32 = jnp.float32


# -------------------------------------------------------- metric primitives

def mape(pred, actual, eps: float = 1e-6):
    """Mean absolute percentage error |pred - actual| / |actual| over the
    trailing axis (ordered ``hour_sum`` mean — batch-invariant); 1-D
    inputs return the per-element APE. Always >= 0."""
    e = jnp.abs(pred - actual) / jnp.clip(jnp.abs(actual), eps, None)
    if e.ndim > 1:
        return hour_sum(e) / e.shape[-1]
    return e


def bias(pred, actual, eps: float = 1e-6):
    """Signed relative error (pred - actual) / |actual|, trailing-axis
    mean for >=2-D inputs. A zero-error forecast gives exactly 0.0."""
    e = (pred - actual) / jnp.clip(jnp.abs(actual), eps, None)
    if e.ndim > 1:
        return hour_sum(e) / e.shape[-1]
    return e


def coverage(bound, actual):
    """Empirical coverage: fraction of trailing-axis entries with
    ``actual <= bound`` (in [0, 1] by construction); 1-D inputs return
    the 0/1 indicator."""
    ok = (actual <= bound).astype(f32)
    if ok.ndim > 1:
        return hour_sum(ok) / ok.shape[-1]
    return ok


def level_drift(fc_level, trailing, eps: float = 1e-6):
    """|forecast daily level - trailing-window mean| / mean: the gauge
    that catches a streaming predictor drifting away from what a rescan
    over the same window would forecast. fc_level (n,); trailing (n, W)."""
    m = hour_sum(trailing) / trailing.shape[-1]
    return jnp.abs(fc_level - m) / jnp.clip(jnp.abs(m), eps, None)


# ------------------------------------------------------- the in-graph record

class DayTelemetry(NamedTuple):
    """One day's diagnostics, per rollout. n = clusters, m = campuses,
    T = solver outer rounds. The cluster/campus axes are NOT reduced
    in-graph (host-side consumers reduce them — same convention as the
    Ledger), so stacking under scan/vmap yields (days, ...) and
    (batch, days, ...) leaves."""
    # --- solver convergence (core.vcc / core.spatial channels)
    obj_cluster_traj: jnp.ndarray     # (T, n) nominal cost per outer round
    step_max_traj: jnp.ndarray        # (T, n) max |delta step| per round
    conservation_resid: jnp.ndarray   # (n,)  |sum_h delta| at the solution
    proj_nu_tol: jnp.ndarray          # (n,)  certified bisection tolerance
    dual_resid: jnp.ndarray           # (m,)  relative campus overshoot
    cvar_tail_mass: jnp.ndarray       # (n,)  max CVaR member weight
    joint_winner: jnp.ndarray         # ()    1.0 = joint refinement kept
    # --- forecast calibration (vs the realized day)
    uif_mape: jnp.ndarray             # (n,) hourly U_IF forecast MAPE
    uif_bias: jnp.ndarray             # (n,) hourly U_IF signed rel. error
    tuf_mape: jnp.ndarray             # (n,) daily flexible-total MAPE
    tuf_bias: jnp.ndarray             # (n,)
    tr_mape: jnp.ndarray              # (n,) daily reservation-total MAPE
    tr_bias: jnp.ndarray              # (n,)
    theta_covered: jnp.ndarray        # (n,) 1.0 if realized T_R <= Theta
    uifq_coverage: jnp.ndarray        # (n,) frac hours U_IF <= (1-g) quant
    fc_level_drift: jnp.ndarray       # (n,) forecast-vs-trailing-week drift
    # --- SLO / headroom gauges
    vcc_binding_frac: jnp.ndarray     # (n,) frac hours reservations at VCC
    queue_age_days: jnp.ndarray       # (n,) backlog / daily service rate
    paused: jnp.ndarray               # (n,) 1.0 = SLO pause active
    shaped: jnp.ndarray               # (n,) 1.0 = cluster actively shaped
    # --- intra-day MPC recourse (core.mpc; zeros when StageConfig.mpc
    # is off so the telemetry pytree stays config-independent)
    mpc_recourse_frac: jnp.ndarray    # (n,) frac hours re-planned
    mpc_recourse_depth: jnp.ndarray   # (n,) mean |delta change| if re-planned


def day_telemetry(sdiag: Dict[str, jnp.ndarray], fc, res, u_if, vcc_curve,
                  *, pause_left, shaped, trail,
                  recourse=None) -> DayTelemetry:
    """Assemble the day's DayTelemetry inside the jitted step.

    ``sdiag``: the optimize_stage solver-diagnostics dict; ``fc``: the
    forecast dict the day optimized against; ``res``: the shaped
    admission DayResult; ``u_if``: realized inflexible load (n, 24);
    ``trail``: dict of trailing-week daily levels {uif, tuf, tr} (n, 7)
    — the pred rings in streaming mode, the hist window tails in rescan
    mode; ``recourse``: the ``core.mpc.MPCDiag`` of the day when
    StageConfig.mpc (None = open loop, recorded as zeros).
    Barrier-pinned: telemetry must never change how the channels it taps
    re-fuse. Note ``vcc_curve`` is the curve admission actually enforced
    (under mpc the realized hour-by-hour curve), so ``vcc_binding_frac``
    gauges the closed loop, not the stale 00:00 plan."""
    daily_res = hour_sum(res.reservations)
    if recourse is None:
        rec_frac = jnp.zeros_like(daily_res)
        rec_depth = jnp.zeros_like(daily_res)
    else:
        rec_frac = recourse.recourse_frac
        rec_depth = recourse.recourse_depth
    drift = jnp.maximum(
        jnp.maximum(level_drift(hour_sum(fc["uif"]), trail["uif"]),
                    level_drift(fc["tuf"], trail["tuf"])),
        level_drift(fc["tr"], trail["tr"]))
    rec = DayTelemetry(
        obj_cluster_traj=sdiag["obj_cluster_traj"],
        step_max_traj=sdiag["step_max_traj"],
        conservation_resid=sdiag["conservation_resid"],
        proj_nu_tol=sdiag["proj_nu_tol"],
        dual_resid=sdiag["dual_resid"],
        cvar_tail_mass=sdiag["cvar_tail_mass"],
        joint_winner=sdiag["joint_winner"],
        uif_mape=mape(fc["uif"], u_if),
        uif_bias=bias(fc["uif"], u_if),
        tuf_mape=mape(fc["tuf"], res.served),
        tuf_bias=bias(fc["tuf"], res.served),
        tr_mape=mape(fc["tr"], daily_res),
        tr_bias=bias(fc["tr"], daily_res),
        theta_covered=(daily_res <= fc["theta"]).astype(f32),
        uifq_coverage=coverage(fc["uif_q"], u_if),
        fc_level_drift=drift,
        # an hour is "binding" when reservations reach the VCC (within
        # 0.1% — admission saturates at the curve, never above it)
        vcc_binding_frac=coverage(res.reservations, 0.999 * vcc_curve),
        queue_age_days=res.queue_end / jnp.clip(res.served, 1e-6, None),
        paused=(pause_left > 0).astype(f32),
        shaped=shaped.astype(f32),
        mpc_recourse_frac=rec_frac,
        mpc_recourse_depth=rec_depth)
    return jax.lax.optimization_barrier(rec)


# ---------------------------------------------------------- trace exporting

# one JSON record per scenario x seed x day; cluster/campus axes reduced
# host-side (fleet mean for calibration rates, max for residuals/ages)
TRACE_FIELDS = (
    "scenario", "seed", "day",
    "obj_first", "obj_final", "obj_decrease_pct", "step_final",
    "conservation_max", "proj_tol_max", "dual_max", "cvar_tail_max",
    "joint_winner",
    "uif_mape", "uif_bias", "tuf_mape", "tuf_bias", "tr_mape", "tr_bias",
    "theta_coverage", "uifq_coverage", "fc_level_drift",
    "vcc_binding_frac", "queue_age_max", "paused_frac", "shaped_frac",
    "mpc_recourse_frac", "mpc_recourse_depth",
)


def telemetry_records(tel: DayTelemetry, scenario_names: Sequence[str],
                      n_seeds: int) -> List[Dict[str, object]]:
    """Flatten a batched rollout's stacked telemetry — leaves shaped
    (scenario x seed, days, ...), scenario-major seed-minor (the
    ``scenarios.build_batch`` layout) — into TRACE_FIELDS records."""
    t = jax.tree.map(lambda a: np.asarray(a, dtype=np.float64), tel)
    batch, days = t.uif_mape.shape[:2]
    if batch != len(scenario_names) * n_seeds:
        raise ValueError(
            f"telemetry batch of {batch} rollouts != {len(scenario_names)} "
            f"scenarios x {n_seeds} seeds")
    records = []
    for b in range(batch):
        scen = scenario_names[b // n_seeds]
        seed = b % n_seeds
        for d in range(days):
            obj_first = float(t.obj_cluster_traj[b, d, 0].sum())
            obj_final = float(t.obj_cluster_traj[b, d, -1].sum())
            records.append({
                "scenario": scen, "seed": seed, "day": d,
                "obj_first": obj_first, "obj_final": obj_final,
                "obj_decrease_pct": 100.0 * (obj_first - obj_final)
                / max(abs(obj_first), 1e-9),
                "step_final": float(t.step_max_traj[b, d, -1].max()),
                "conservation_max": float(t.conservation_resid[b, d].max()),
                "proj_tol_max": float(t.proj_nu_tol[b, d].max()),
                "dual_max": float(t.dual_resid[b, d].max()),
                "cvar_tail_max": float(t.cvar_tail_mass[b, d].max()),
                "joint_winner": float(t.joint_winner[b, d]),
                "uif_mape": float(t.uif_mape[b, d].mean()),
                "uif_bias": float(t.uif_bias[b, d].mean()),
                "tuf_mape": float(t.tuf_mape[b, d].mean()),
                "tuf_bias": float(t.tuf_bias[b, d].mean()),
                "tr_mape": float(t.tr_mape[b, d].mean()),
                "tr_bias": float(t.tr_bias[b, d].mean()),
                "theta_coverage": float(t.theta_covered[b, d].mean()),
                "uifq_coverage": float(t.uifq_coverage[b, d].mean()),
                "fc_level_drift": float(t.fc_level_drift[b, d].max()),
                "vcc_binding_frac": float(t.vcc_binding_frac[b, d].mean()),
                "queue_age_max": float(t.queue_age_days[b, d].max()),
                "paused_frac": float(t.paused[b, d].mean()),
                "shaped_frac": float(t.shaped[b, d].mean()),
                "mpc_recourse_frac": float(
                    t.mpc_recourse_frac[b, d].mean()),
                "mpc_recourse_depth": float(
                    t.mpc_recourse_depth[b, d].mean()),
            })
    return records


def write_jsonl(path, records: Sequence[Dict[str, object]]) -> None:
    """One JSON object per line (the CI trace-artifact format)."""
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")


def read_jsonl(path) -> List[Dict[str, object]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# --------------------------------------------------- stage cost attribution

def _time_compiled(fn, args, reps: int):
    """(compiled HLO text, best-of-reps wall seconds) of jit(fn)(*args)."""
    f = jax.jit(fn)
    text = f.lower(*args).compile().as_text()
    out = f(*args)                      # warm-up (compile + first run)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return text, best


def profile_stages(cfg: stages.StageConfig, params, state,
                   reps: int = 3) -> List[Dict[str, object]]:
    """Attribute compiled cost per stage of the day cycle.

    Compiles each stage standalone at the shapes of ``(params, state)``
    (a burned-in SimState), reads static dot FLOPs/bytes from the
    compiled HLO (``launch.hlo_analysis.analyze_hlo`` — while-loop trip
    counts multiplied through, so the PGD scan is costed per-iteration),
    and times best-of-``reps`` wall clock with ``block_until_ready``.
    Returns rows {stage, wall_ms, pct, dot_flops, dot_bytes}; ``pct`` is
    the share of summed per-stage wall time, plus a final ``day_step``
    row timing the full fused step (its wall_ms < the stage sum is the
    fusion win; pct is relative to the same stage sum)."""
    from repro.launch.hlo_analysis import analyze_hlo

    n = state.queue.shape[0]
    m = state.campus_limit.shape[0]
    z = state.carbon_hist.shape[0]
    xs = stages.ones_xs(n, m, z)
    day_key = jax.random.fold_in(params.key, state.day)
    pdt = stages.pd_truth(params)
    cap = params.truth["capacity"]
    hist_usage = state.pred.usage_ring if cfg.streaming else state.hist_usage

    def power_fn(hist, key):
        return stages.power_stage(hist, params.lam, cap, pdt, key)

    if cfg.streaming:
        def forecast_fn(day, gamma):
            return stages.forecast_stage_streaming(state.pred, day, gamma)
        forecast_args = (state.day, params.gamma)
    else:
        forecast_fn = stages.forecast_stage
        forecast_args = (state.hist_uif, state.hist_flex_daily,
                         state.hist_res_daily, state.hist_usage,
                         state.hist_res, state.hist_tr_pred,
                         state.hist_uif_pred, state.day, params.gamma)

    def carbon_fn(hist, key):
        return stages.carbon_stage(params.zone, hist, key,
                                   xs["green_scale"], xs["coal_scale"])

    # eager prerequisites for the downstream stages
    model = power_fn(hist_usage, jax.random.fold_in(day_key, 1))
    fc = forecast_fn(*forecast_args)
    act_z, fc_z = carbon_fn(state.carbon_hist,
                            jax.random.fold_in(day_key, 4))
    eta_act, eta_fc = act_z[state.zmap], fc_z[state.zmap]
    ens = None
    if cfg.n_members > 1:
        from repro.core import risk
        ens = risk.day_ensembles(
            jax.random.fold_in(day_key, 5), cfg.n_members, fc["uif"],
            state.hist_uif_pred, state.hist_uif, fc_z, state.carbon_hist,
            state.zmap, params.risk_beta)

    def optimize_fn(fcv, eta, queue, u_pow_cap, cap_day, campus_limit):
        return stages.optimize_stage(
            cfg, fcv, eta, model, queue, u_pow_cap, cap_day, state.campus,
            campus_limit, params.lambda_e, params.lambda_p,
            params.mobility, ens=ens)

    _, sol, _ = optimize_fn(fc, eta_fc, state.queue, state.u_pow_cap, cap,
                            state.campus_limit)
    gate = state.shaping_allowed & sol.shaped
    vcc_curve = jnp.where(gate[:, None], sol.vcc, cap[:, None] * 10.0)

    def observe_fn(curve, cap_day, queue, cf_queue, eta):
        return stages.observe_stage(
            params.truth, state.day, day_key, curve, cap_day,
            xs["arrival_scale"], queue, cf_queue,
            lambda u: stages.model_power(model, u), eta)

    entries = [
        ("power_fit", power_fn,
         (hist_usage, jax.random.fold_in(day_key, 1))),
        ("forecast", forecast_fn, forecast_args),
        ("carbon", carbon_fn,
         (state.carbon_hist, jax.random.fold_in(day_key, 4))),
        ("optimize", optimize_fn,
         (fc, eta_fc, state.queue, state.u_pow_cap, cap,
          state.campus_limit)),
        ("observe", observe_fn,
         (vcc_curve, cap, state.queue, state.cf_queue, eta_act)),
    ]
    rows: List[Dict[str, object]] = []
    for name, fn, args in entries:
        text, secs = _time_compiled(fn, args, reps)
        summ = analyze_hlo(text)
        rows.append({"stage": name, "wall_ms": secs * 1e3,
                     "dot_flops": summ.dot_flops,
                     "dot_bytes": summ.dot_bytes})
    stage_total = sum(r["wall_ms"] for r in rows)
    step = stages.jitted_day_step(cfg)
    text = step.lower(params, state, xs).compile().as_text()
    jax.block_until_ready(step(params, state, xs))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(step(params, state, xs))
        best = min(best, time.perf_counter() - t0)
    summ = analyze_hlo(text)
    rows.append({"stage": "day_step", "wall_ms": best * 1e3,
                 "dot_flops": summ.dot_flops, "dot_bytes": summ.dot_bytes})
    for r in rows:
        r["pct"] = 100.0 * r["wall_ms"] / max(stage_total, 1e-9)
    return rows


def format_stage_table(rows: List[Dict[str, object]]) -> str:
    """Fixed-width stage-cost table (the CI PR-comment rendering)."""
    name_w = max([len("stage")] + [len(r["stage"]) for r in rows]) + 2
    out = ["stage".ljust(name_w) + "   wall_ms      pct     dot_GFLOP"
           + "    dot_MB"]
    out.append("-" * (name_w + 44))
    for r in rows:
        out.append(r["stage"].ljust(name_w)
                   + f"{r['wall_ms']:9.2f}  {r['pct']:6.1f}%  "
                   + f"{r['dot_flops'] / 1e9:12.3f}  "
                   + f"{r['dot_bytes'] / 1e6:8.2f}")
    return "\n".join(out)


__all__ = [
    "DayTelemetry", "day_telemetry", "mape", "bias", "coverage",
    "level_drift", "telemetry_records", "write_jsonl", "read_jsonl",
    "profile_stages", "format_stage_table", "TRACE_FIELDS",
]
