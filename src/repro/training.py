"""Step builders shared by the trainer, server and dry-run driver."""
from __future__ import annotations


import jax

from repro.optim import AdamWConfig, adamw_update


def make_train_step(model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step
