import os
import sys

# tests must see exactly ONE device (the dry-run entrypoint forces 512 for
# itself; never set that globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
