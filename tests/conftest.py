import os
import sys

# tests must see exactly ONE device (the dry-run entrypoint forces 512 for
# itself; never set that globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Hypothesis profiles (property tests skip as a unit where the package is
# absent — see the importorskip capability checks). The "ci" profile pins
# the PRNG seed and disables deadlines so property tests are reproducible
# and immune to shared-runner jitter; the workflow selects it via
# HYPOTHESIS_PROFILE=ci. Locally the default profile keeps exploring.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        derandomize=True,            # fixed example seed: reproducible CI
        deadline=None,               # jit compile times dwarf any deadline
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # capability absent: property-test modules skip
    pass
