"""Admission control + SLO feedback semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, slo


def _power_fn(u):
    return 100.0 + 300.0 * u


def test_inflexible_never_curtailed():
    """Design principle: shaping must only impact flexible workload."""
    n = 3
    vcc = jnp.zeros((n, 24))            # pathological: zero capacity
    u_if = jnp.full((n, 24), 2.0)
    arrivals = jnp.full((n, 24), 1.0)
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 10.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    np.testing.assert_allclose(np.asarray(res.usage_total),
                               np.asarray(u_if))     # inflexible untouched
    assert float(res.usage_flex.sum()) == 0.0        # flexible fully queued


def test_vcc_caps_reservations():
    n = 2
    vcc = jnp.full((n, 24), 5.0)
    u_if = jnp.full((n, 24), 1.0)
    arrivals = jnp.full((n, 24), 10.0)              # way more than capacity
    ratio = jnp.full((n, 24), 1.25)
    res = admission.run_day(vcc, u_if, arrivals, ratio,
                            jnp.full((n,), 100.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    assert bool(jnp.all(res.reservations <= vcc + 1e-4))


def test_queue_conservation():
    n = 2
    key = jax.random.PRNGKey(0)
    vcc = 4.0 + jax.random.uniform(key, (n, 24))
    u_if = jnp.full((n, 24), 1.0)
    arrivals = 2.0 * jax.random.uniform(jax.random.fold_in(key, 1), (n, 24))
    q0 = jnp.asarray([3.0, 0.0])
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 100.0), q0, _power_fn,
                            jnp.full((n, 24), 0.3))
    lhs = np.asarray(q0 + res.arrived)
    rhs = np.asarray(res.served + res.queue_end)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


def test_ample_capacity_serves_everything():
    n = 2
    vcc = jnp.full((n, 24), 100.0)
    u_if = jnp.full((n, 24), 1.0)
    arrivals = jnp.full((n, 24), 2.0)
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 200.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    np.testing.assert_allclose(float(res.served.sum()),
                               float(res.arrived.sum()), rtol=1e-6)
    assert float(res.unmet.sum()) == 0.0


def test_slo_two_day_trigger_and_pause():
    cfg = slo.SLOConfig(pause_days=7)
    st = slo.init_state(2)
    res_demand = jnp.asarray([101.0, 50.0])
    budget = jnp.asarray([100.0, 100.0])
    unmet = jnp.zeros((2,))
    arrived = jnp.full((2,), 10.0)
    st, allowed = slo.update(st, cfg, res_demand, budget, unmet, arrived)
    assert bool(allowed[0]) and bool(allowed[1])     # 1 crowded day: fine
    st, allowed = slo.update(st, cfg, res_demand, budget, unmet, arrived)
    assert not bool(allowed[0])                      # 2 in a row: paused
    assert bool(allowed[1])
    for _ in range(6):
        st, allowed = slo.update(st, cfg, jnp.zeros((2,)), budget, unmet,
                                 arrived)
        assert not bool(allowed[0])
    st, allowed = slo.update(st, cfg, jnp.zeros((2,)), budget, unmet,
                             arrived)
    assert bool(allowed[0])                          # pause expired


def test_slo_persistently_crowded_resumes_after_exactly_pause_days():
    """Regression: the crowded streak must FREEZE while a pause is
    active. The old code kept accumulating crowded days during the
    pause, so a persistently busy cluster re-triggered a fresh pause the
    moment the old one expired and never resumed shaping."""
    cfg = slo.SLOConfig(pause_days=3)
    st = slo.init_state(1)
    crowded = jnp.asarray([150.0])
    budget = jnp.asarray([100.0])
    unmet = jnp.zeros((1,))
    arrived = jnp.ones((1,))
    allowed_hist = []
    for _ in range(12):                 # crowded EVERY day
        st, allowed = slo.update(st, cfg, crowded, budget, unmet, arrived)
        allowed_hist.append(bool(allowed[0]))
    # day1: streak 1 (allowed). day2: trigger -> 3 disallowed days
    # (days 2-4). day5: pause expired -> shaping resumes for one day.
    # days 6-7 rebuild the streak, day 7 re-triggers, and so on.
    assert allowed_hist[:8] == [True, False, False, False,
                                True, True, False, False]
    # shaping must resume at least once after the first pause
    paused_days = allowed_hist[1:].index(True)
    assert paused_days == cfg.pause_days            # exactly pause_days


def test_violation_rate_accounting():
    cfg = slo.SLOConfig()
    st = slo.init_state(1)
    for i in range(10):
        unmet = jnp.asarray([1.0 if i < 3 else 0.0])
        st, _ = slo.update(st, cfg, jnp.zeros((1,)), jnp.ones((1,)), unmet,
                           jnp.ones((1,)))
    assert abs(float(slo.violation_rate(st)[0]) - 0.3) < 1e-6


def test_violation_threshold_scale_invariant():
    """Regression: a day is violated when unmet exceeds rel_tol x
    arrivals — the detector must fire identically on a 10-CPU-h synthetic
    cluster and a 10k-CPU-h production one (the old absolute
    ``unmet > 0.1`` threshold flagged every large cluster and no small
    one)."""
    cfg = slo.SLOConfig(rel_tol=1e-3)
    for scale in (1.0, 1e4):
        arrived = jnp.asarray([scale, scale])
        # cluster 0: unmet = 2e-3 of arrivals (violated);
        # cluster 1: unmet = 5e-4 of arrivals (within tolerance)
        unmet = jnp.asarray([2e-3 * scale, 5e-4 * scale])
        st = slo.init_state(2)
        st, _ = slo.update(st, cfg, jnp.zeros((2,)), jnp.ones((2,)),
                           unmet, arrived)
        assert st["violation_days"].tolist() == [1, 0], f"scale={scale}"


def test_allowance_frac_threaded_through_run_day():
    """The late-arrival allowance is a parameter, not a buried constant:
    unmet = max(queue growth - allowance_frac * arrivals, 0)."""
    n = 1
    vcc = jnp.zeros((n, 24))            # nothing served: all flex queues
    u_if = jnp.zeros((n, 24))
    arrivals = jnp.full((n, 24), 1.0)   # 24 CPU-h arrive, 0 served
    args = (vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
            jnp.full((n,), 10.0), jnp.zeros((n,)), _power_fn,
            jnp.full((n, 24), 0.3))
    res_default = admission.run_day(*args)
    res_half = admission.run_day(*args, allowance_frac=0.5)
    np.testing.assert_allclose(float(res_default.unmet[0]),
                               (1.0 - 0.25) * 24.0, rtol=1e-6)
    np.testing.assert_allclose(float(res_half.unmet[0]),
                               (1.0 - 0.5) * 24.0, rtol=1e-6)
