"""Admission control + SLO feedback semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, slo


def _power_fn(u):
    return 100.0 + 300.0 * u


def test_inflexible_never_curtailed():
    """Design principle: shaping must only impact flexible workload."""
    n = 3
    vcc = jnp.zeros((n, 24))            # pathological: zero capacity
    u_if = jnp.full((n, 24), 2.0)
    arrivals = jnp.full((n, 24), 1.0)
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 10.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    np.testing.assert_allclose(np.asarray(res.usage_total),
                               np.asarray(u_if))     # inflexible untouched
    assert float(res.usage_flex.sum()) == 0.0        # flexible fully queued


def test_vcc_caps_reservations():
    n = 2
    vcc = jnp.full((n, 24), 5.0)
    u_if = jnp.full((n, 24), 1.0)
    arrivals = jnp.full((n, 24), 10.0)              # way more than capacity
    ratio = jnp.full((n, 24), 1.25)
    res = admission.run_day(vcc, u_if, arrivals, ratio,
                            jnp.full((n,), 100.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    assert bool(jnp.all(res.reservations <= vcc + 1e-4))


def test_queue_conservation():
    n = 2
    key = jax.random.PRNGKey(0)
    vcc = 4.0 + jax.random.uniform(key, (n, 24))
    u_if = jnp.full((n, 24), 1.0)
    arrivals = 2.0 * jax.random.uniform(jax.random.fold_in(key, 1), (n, 24))
    q0 = jnp.asarray([3.0, 0.0])
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 100.0), q0, _power_fn,
                            jnp.full((n, 24), 0.3))
    lhs = np.asarray(q0 + res.arrived)
    rhs = np.asarray(res.served + res.queue_end)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


def test_ample_capacity_serves_everything():
    n = 2
    vcc = jnp.full((n, 24), 100.0)
    u_if = jnp.full((n, 24), 1.0)
    arrivals = jnp.full((n, 24), 2.0)
    res = admission.run_day(vcc, u_if, arrivals, jnp.full((n, 24), 1.2),
                            jnp.full((n,), 200.0), jnp.zeros((n,)),
                            _power_fn, jnp.full((n, 24), 0.3))
    np.testing.assert_allclose(float(res.served.sum()),
                               float(res.arrived.sum()), rtol=1e-6)
    assert float(res.unmet.sum()) == 0.0


def test_slo_two_day_trigger_and_pause():
    cfg = slo.SLOConfig(pause_days=7)
    st = slo.init_state(2)
    res_demand = jnp.asarray([101.0, 50.0])
    budget = jnp.asarray([100.0, 100.0])
    unmet = jnp.zeros((2,))
    st, allowed = slo.update(st, cfg, res_demand, budget, unmet)
    assert bool(allowed[0]) and bool(allowed[1])     # 1 crowded day: fine
    st, allowed = slo.update(st, cfg, res_demand, budget, unmet)
    assert not bool(allowed[0])                      # 2 in a row: paused
    assert bool(allowed[1])
    for _ in range(6):
        st, allowed = slo.update(st, cfg, jnp.zeros((2,)), budget, unmet)
        assert not bool(allowed[0])
    st, allowed = slo.update(st, cfg, jnp.zeros((2,)), budget, unmet)
    assert bool(allowed[0])                          # pause expired


def test_violation_rate_accounting():
    cfg = slo.SLOConfig()
    st = slo.init_state(1)
    for i in range(10):
        unmet = jnp.asarray([1.0 if i < 3 else 0.0])
        st, _ = slo.update(st, cfg, jnp.zeros((1,)), jnp.ones((1,)), unmet)
    assert abs(float(slo.violation_rate(st)[0]) - 0.3) < 1e-6
