"""Checkpointing (atomicity, kill/resume, elastic restore) + data pipeline
determinism."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.data import DataConfig, DataLoader, batch_at

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, 7, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(tmp_path, 1, tree)
    ckpt.save(tmp_path, 2, tree)
    os.remove(tmp_path / "step_00000002" / "COMMIT")   # simulate crash
    assert ckpt.latest_step(tmp_path) == 1


def test_gc_keeps_last_k(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in range(6):
        ckpt.save(tmp_path, s, tree, keep=3)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 3 and steps[-1] == "step_00000005"


def test_kill_and_resume_trainer(tmp_path):
    """Hard-kill the trainer mid-run; resume must continue from the last
    committed step and reach the same final state as an uninterrupted run."""
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen3-0.6b", "--smoke", "--steps", "30", "--batch", "2",
            "--seq", "64", "--ckpt-every", "10", "--log-every", "10"]
    # run A: killed at step 17 (after the step-10 checkpoint)
    ra = subprocess.run(base + ["--ckpt-dir", str(tmp_path / "a"),
                                "--kill-at-step", "17"],
                        env=env, capture_output=True, text=True)
    assert ra.returncode == 42, ra.stderr[-2000:]
    assert ckpt.latest_step(tmp_path / "a") == 10
    rb = subprocess.run(base + ["--ckpt-dir", str(tmp_path / "a")],
                        env=env, capture_output=True, text=True)
    assert rb.returncode == 0, rb.stderr[-2000:]
    assert "resumed from step 10" in rb.stdout
    assert ckpt.latest_step(tmp_path / "a") == 30
    # run B: uninterrupted reference
    rc = subprocess.run(base + ["--ckpt-dir", str(tmp_path / "b")],
                        env=env, capture_output=True, text=True)
    assert rc.returncode == 0
    a = np.load(tmp_path / "a" / "step_00000030" / "arrays" / "0.npy")
    b = np.load(tmp_path / "b" / "step_00000030" / "arrays" / "0.npy")
    np.testing.assert_allclose(a, b, atol=1e-5)   # deterministic replay


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    b1 = batch_at(cfg, 5)
    b2 = batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding: two hosts tile the global batch
    l0 = DataLoader(cfg, host_index=0, host_count=2)
    l1 = DataLoader(cfg, host_index=1, host_count=2)
    s0, h0 = next(l0)
    s1, h1 = next(l1)
    l0.close(), l1.close()
    assert s0 == s1 == 0
    full = batch_at(cfg, 0)["tokens"]
    np.testing.assert_array_equal(h0["tokens"], full[:4])
    np.testing.assert_array_equal(h1["tokens"], full[4:])


def test_elastic_restore_changes_placement(tmp_path):
    """Checkpoints are logical arrays; restore works regardless of the
    sharding layout requested (1-device CPU here, but via NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(tmp_path, 0, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out = ckpt.restore(tmp_path, 0, jax.eval_shape(lambda: tree), sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]
