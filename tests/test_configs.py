from repro.configs import ARCHS, SHAPES, get_arch, list_cells


def test_ten_archs_forty_cells():
    assert len(ARCHS) == 10
    cells = list_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] is None]
    assert len(runnable) == 32          # 8 documented long_500k skips


def test_assigned_configs_exact():
    c = get_arch("yi-6b").config
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 11008, 64000)
    assert (c.attn.num_heads, c.attn.num_kv_heads) == (32, 4)
    c = get_arch("deepseek-67b").config
    assert (c.num_layers, c.d_model, c.attn.num_heads,
            c.attn.num_kv_heads, c.d_ff, c.vocab_size) == \
        (95, 8192, 64, 8, 22016, 102400)
    c = get_arch("qwen3-0.6b").config
    assert c.attn.qk_norm and c.tie_embeddings and c.vocab_size == 151936
    c = get_arch("gemma2-9b").config
    assert c.attn.pattern == "local_global" and c.logit_softcap == 30.0
    assert c.attn.attn_softcap == 50.0 and c.vocab_size == 256000
    c = get_arch("deepseek-moe-16b").config
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared,
            c.moe.d_expert) == (64, 6, 2, 1408)
    c = get_arch("deepseek-v2-236b").config
    assert (c.moe.num_experts, c.moe.top_k, c.mla.kv_lora_rank) == \
        (160, 6, 512)
    assert c.num_layers == 60 and c.d_model == 5120
    c = get_arch("zamba2-7b").config
    assert c.family == "hybrid" and c.num_layers == 81 \
        and c.ssm.state_dim == 64
    c = get_arch("whisper-base").config
    assert c.family == "encdec" and c.encoder_layers == 6 \
        and c.vocab_size == 51865
    c = get_arch("rwkv6-7b").config
    assert c.family == "ssm" and c.attn is None and c.vocab_size == 65536
    c = get_arch("internvl2-2b").config
    assert c.family == "vlm" and c.vision_tokens == 256


def test_subquadratic_runs_long_500k():
    for name in ("rwkv6-7b", "zamba2-7b"):
        assert "long_500k" not in get_arch(name).skip_shapes
    for name in ("yi-6b", "gemma2-9b", "deepseek-v2-236b", "whisper-base"):
        assert "long_500k" in get_arch(name).skip_shapes


def test_shapes():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524_288
