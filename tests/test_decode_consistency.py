"""prefill + decode_step must reproduce the full-forward logits for every
architecture family (KV caches, MLA absorption, SSM/RWKV states, MoE
no-drop decode, whisper cross-attention cache)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_full_forward(name):
    cfg = get_arch(name).smoke.replace(dtype="float32", remat="none")
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    B, T = 2, 17
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        extra["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    params = model.init(key)
    maxs = T + 8
    b = dict(extra)
    b["tokens"] = toks[:, :T - 1]
    _, cache = model.prefill(params, b, maxs)
    pos = T - 1 + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    lg_dec, cache = model.decode_step(params, cache, toks[:, T - 1],
                                      jnp.asarray(pos, jnp.int32))
    b2 = dict(extra)
    b2["tokens"] = toks
    lg_ref, _ = model.prefill(params, b2, maxs)
    rel = np.abs(np.asarray(lg_dec) - np.asarray(lg_ref)).max() / (
        np.abs(np.asarray(lg_ref)).max() + 1e-9)
    assert rel < 2e-3, (name, rel)


@pytest.mark.parametrize("name", ["yi-6b", "rwkv6-7b", "zamba2-7b"])
def test_multi_step_decode(name):
    """Decode 4 tokens sequentially; each must match teacher forcing."""
    cfg = get_arch(name).smoke.replace(dtype="float32", remat="none")
    model = build_model(cfg)
    key = jax.random.PRNGKey(7)
    B, T, G = 2, 9, 4
    toks = jax.random.randint(key, (B, T + G), 0, cfg.vocab_size)
    params = model.init(key)
    maxs = T + G + 2
    _, cache = model.prefill(params, {"tokens": toks[:, :T]}, maxs)
    # prefill consumed tokens [0, T); each decode step feeds token T+i at
    # position T+i and must match the full forward over [0, T+i].
    for i in range(G):
        lg, cache = model.decode_step(params, cache, toks[:, T + i],
                                      jnp.asarray(T + i, jnp.int32))
        lg_ref, _ = model.prefill(params, {"tokens": toks[:, :T + i + 1]},
                                  maxs)
        rel = np.abs(np.asarray(lg) - np.asarray(lg_ref)).max() / (
            np.abs(np.asarray(lg_ref)).max() + 1e-9)
        assert rel < 2e-3, (name, i, rel)
