"""Dry-run machinery on a single-device mesh: sharding specs are
well-formed, lowering works, and the loop-aware HLO analyzer counts
scan-trip-multiplied FLOPs/collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import build_model, param_specs
from repro.optim import AdamWConfig, init_opt_state
from repro.sharding import param_pspecs
from repro.training import make_train_step


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def test_param_pspecs_cover_all_archs():
    mesh = _mesh()
    for name in ("yi-6b", "deepseek-v2-236b", "zamba2-7b", "rwkv6-7b",
                 "whisper-base", "internvl2-2b"):
        cfg = get_arch(name).config
        specs = param_pspecs(cfg, param_specs(cfg), mesh)
        for (path, spec), (_, leaf) in zip(
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: hasattr(x, "index"))[0],
                jax.tree_util.tree_flatten_with_path(param_specs(cfg))[0]):
            assert len(spec) == len(leaf.shape), (name, path)


def test_smoke_train_lowering_and_analysis():
    cfg = get_arch("yi-6b").smoke
    model = build_model(cfg)
    opt_cfg = AdamWConfig()
    p = param_specs(cfg)
    o = jax.eval_shape(lambda: init_opt_state(p, opt_cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 65), jnp.int32)}
    step = make_train_step(model, opt_cfg)
    lowered = jax.jit(step).lower(p, o, batch)
    compiled = lowered.compile()
    s = analyze_hlo(compiled.as_text())
    assert s.dot_flops > 0
    # layer scan must be trip-counted: 2 layers for the smoke config
    trips = dict(s.loops)
    assert any(t >= cfg.num_layers for t in trips.values()), s.loops
    # ideal model flops: 6 * N * D within a factor covering attention +
    # rematerialization overheads
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    tokens = 4 * 64
    ideal = 6 * n_params * tokens
    assert s.dot_flops > 0.5 * ideal
    assert s.dot_flops < 6 * ideal


def test_analyzer_counts_collectives_in_loops():
    txt = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %a)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    s = analyze_hlo(txt)
    assert s.collectives["all-reduce"]["count"] == 7      # trip-counted
    assert s.collectives["all-reduce"]["bytes"] == 7 * 32


def test_full_config_param_count_sane():
    """Full-config parameter totals are within 20% of published sizes."""
    expected = {"yi-6b": 6.1e9, "deepseek-67b": 67e9, "qwen3-0.6b": 0.6e9,
                "gemma2-9b": 9.2e9, "deepseek-moe-16b": 16.4e9,
                "deepseek-v2-236b": 236e9, "zamba2-7b": 7.2e9,
                "rwkv6-7b": 7.6e9, "whisper-base": 72e6}
    for name, want in expected.items():
        cfg = get_arch(name).config
        p = param_specs(cfg)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
        assert abs(n - want) / want < 0.20, (name, n, want)
