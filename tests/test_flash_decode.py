"""MLA flash-decode (shard_map over a sequence-sharded latent cache) must
match the baseline decode path exactly. Runs in a subprocess so the forced
8-device host platform never leaks into other tests."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_arch
from repro.models import build_model
from repro.sharding.act import activation_sharding
from repro.launch.mesh import use_mesh

cfg = get_arch('deepseek-v2-236b').smoke.replace(dtype='float32',
                                                 remat='none')
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
model = build_model(cfg)
key = jax.random.PRNGKey(0)
B, T = 4, 13
toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
params = model.init(key)
maxs = 32
_, cache = model.prefill(params, {'tokens': toks[:, :T-1]}, maxs)
lg_base, _ = model.decode_step(params, cache, toks[:, T-1],
                               jnp.asarray(T-1, jnp.int32))
mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8])
model2 = build_model(cfg.replace(flash_decode=True))
with use_mesh(mesh), activation_sharding(mesh):
    _, cache2 = model2.prefill(params, {'tokens': toks[:, :T-1]}, maxs)
    lg_flash, _ = jax.jit(model2.decode_step)(params, cache2, toks[:, T-1],
                                              jnp.asarray(T-1, jnp.int32))
rel = np.abs(np.asarray(lg_flash) - np.asarray(lg_base)).max() / (
    np.abs(np.asarray(lg_base)).max() + 1e-9)
assert rel < 2e-3, rel
print("FLASH_DECODE_OK", rel)
"""


def test_mla_flash_decode_matches_baseline():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "FLASH_DECODE_OK" in r.stdout
