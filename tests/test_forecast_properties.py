"""Hypothesis property tests for core/forecast.py (PR 3 satellite).

Pinned invariants of the day-ahead forecasting pipeline (paper §III-B1 /
eq. 2-3): quantile monotonicity, EWMA/weekly-mean boundedness, and the
eq. 3 alpha inflation being >= 1 and non-decreasing in the trailing
forecast error on self-consistent inputs.

Skips as a unit when the `hypothesis` capability is absent (the CI
workflow installs it and runs these under the fixed-seed `ci` profile
registered in conftest.py).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="capability check: the `hypothesis` package is not importable "
           "here; CI installs it (see .github/workflows/ci.yml) and runs "
           "these property tests under the fixed-seed 'ci' profile")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import forecast  # noqa: E402

SET = dict(max_examples=25, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(
    pred=hnp.arrays(np.float32, (30,),
                    elements=st.floats(0.5, 10.0, width=32)),
    act=hnp.arrays(np.float32, (30,),
                   elements=st.floats(0.1, 20.0, width=32)),
    q1=st.floats(0.05, 0.95),
    dq=st.floats(0.0, 0.049),
)
@settings(**SET)
def test_relative_error_quantile_monotone_in_q(pred, act, q1, dq):
    """Higher quantile level -> larger (1-gamma) error inflation: the
    power-capping chance constraint tightens monotonically with gamma."""
    lo = forecast.relative_error_quantile(jnp.asarray(pred),
                                          jnp.asarray(act), q1)
    hi = forecast.relative_error_quantile(jnp.asarray(pred),
                                          jnp.asarray(act), q1 + dq)
    assert float(hi) >= float(lo) - 1e-6


@given(
    x=hnp.arrays(np.float32, (21,),
                 elements=st.floats(0.0, 100.0, width=32)),
    hl=st.floats(0.1, 16.0),
)
@settings(**SET)
def test_ewma_bounded_by_input_range(x, hl):
    """EWMA is a convex combination chain: the level never escapes
    [min(x), max(x)]."""
    level = float(forecast.ewma(jnp.asarray(x), hl))
    assert x.min() - 1e-4 <= level <= x.max() + 1e-4


@given(
    daily=hnp.arrays(np.float32, (28,),
                     elements=st.floats(0.1, 50.0, width=32)),
    hl=st.floats(0.1, 8.0),
)
@settings(**SET)
def test_weekly_mean_forecast_bounded_by_input_range(daily, hl):
    """The weekly-mean forecast averages then EWMAs: it stays within the
    range of the daily history."""
    fc = float(forecast.weekly_mean_forecast(jnp.asarray(daily), hl))
    assert daily.min() - 1e-4 <= fc <= daily.max() + 1e-4


@given(
    uif=hnp.arrays(np.float32, (24,),
                   elements=st.floats(0.1, 5.0, width=32)),
    tuf=st.floats(0.5, 20.0),
    ratio_a=st.floats(1.05, 2.0),
    eps=st.floats(0.0, 2.0),
    deps=st.floats(0.0, 1.0),
)
@settings(**SET)
def test_alpha_inflation_geq_one_and_monotone_in_error(uif, tuf, ratio_a,
                                                       eps, deps):
    """eq. 3 semantics on self-consistent inputs: when the reservations
    forecast equals the reservations implied by (uif, tuf, R) exactly,
    alpha == 1 at zero trailing error, alpha >= 1 for any eps_q97 >= 0,
    and alpha is non-decreasing in eps (less accurate forecasts inflate
    the flexible budget more). The production pipeline clips to
    [0.5, 4.0] because real histories need not be self-consistent."""
    uif_j = jnp.asarray(uif)
    tuf_j = jnp.asarray(tuf, jnp.float32)
    a = jnp.asarray(ratio_a, jnp.float32)
    b = jnp.zeros((), jnp.float32)          # flat ratio: R == ratio_a
    u_nom = uif_j + tuf_j / 24.0
    r = forecast.ratio_at(a, b, u_nom)
    tr_consistent = jnp.sum((uif_j + tuf_j / 24.0) * r)

    def alpha_at(e):
        theta = forecast.theta_requirement(tr_consistent,
                                           jnp.asarray(e, jnp.float32))
        return float(forecast.alpha_inflation(theta, uif_j, tuf_j, a, b))

    a0 = alpha_at(0.0)
    assert abs(a0 - 1.0) < 5e-3             # perfect forecast -> alpha 1
    a1, a2 = alpha_at(eps), alpha_at(min(eps + deps, 2.0))
    assert a1 >= 1.0 - 5e-3                 # (f32 sum accumulation slack)
    assert a2 >= a1 - 1e-5                  # monotone in trailing error


@given(
    tr=st.floats(0.1, 100.0),
    eps=st.floats(-1.0, 3.0),
)
@settings(**SET)
def test_theta_requirement_bounds(tr, eps):
    """Theta = T_R-hat * (1 + clip(eps, 0, 2)): never below the forecast,
    at most 3x it (eq. 2 with the production clip)."""
    theta = float(forecast.theta_requirement(
        jnp.asarray(tr, jnp.float32), jnp.asarray(eps, jnp.float32)))
    assert tr * (1.0 - 1e-6) <= theta <= 3.0 * tr * (1.0 + 1e-6)
