"""Golden-trace regression: a frozen 3-day, 8-cluster `rollout_batch`
rollout must reproduce BITWISE on CPU.

PR 2's refactor safety net was transient legacy==engine parity — two
adapters over the same staged core agree, but BOTH can drift together
(and the legacy adapters may eventually go away). This trace pins the
absolute numbers: any change to the staged day cycle, the batched engine,
or the batch-invariant numerics that shifts a single bit of the default
(n_members=1) path fails here and must either be a bug or consciously
regenerate the trace:

    PYTHONPATH=src python tests/test_golden_trace.py

The freeze is CPU-only (the bitwise contract is per-backend; TPU/GPU
rounding differs by design) and covers the ledger, the per-day trajectory,
and the carried final state. Scenarios exercise both a perturbation-free
baseline and a price override.
"""
import os

import jax
import numpy as np
import pytest

from repro.sim import SimConfig, Scenario, build_batch, rollout_batch

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "day3.npz")

CFG = SimConfig(n_clusters=8, n_campuses=2, n_zones=2, pds_per_cluster=2,
                hist_days=14)
DAYS = 3
SCENARIOS = (Scenario("baseline", "nominal grid, nominal fleet"),
             Scenario("high_carbon_price", "lambda_e x4", lambda_e=2.0))
SEEDS = (0, 1)


def golden_rollout():
    """The frozen configuration: 2 scenarios x 2 seeds x 3 days."""
    batch = build_batch(CFG, list(SCENARIOS), list(SEEDS), DAYS)
    state, ledger, traj = rollout_batch(CFG, DAYS)(batch)
    out = {}
    for name, val in ledger._asdict().items():
        out[f"ledger_{name}"] = np.asarray(val)
    for name, val in traj.items():
        out[f"traj_{name}"] = np.asarray(val)
    for name in ("queue", "cf_queue", "hist_flex_daily", "hist_res_daily",
                 "carbon_hist", "shaping_allowed", "pause_left"):
        out[f"state_{name}"] = np.asarray(getattr(state, name))
    return out


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="golden trace is frozen on CPU numerics; other "
                           "backends round differently by design")
def test_day3_rollout_matches_golden_trace():
    assert os.path.exists(GOLDEN), \
        f"{GOLDEN} missing — regenerate with " \
        "`PYTHONPATH=src python tests/test_golden_trace.py`"
    want = np.load(GOLDEN)
    got = golden_rollout()
    assert set(want.files) == set(got), \
        f"golden key set changed: {sorted(set(want.files) ^ set(got))}"
    for name in want.files:
        np.testing.assert_array_equal(
            want[name], got[name],
            err_msg=f"{name} drifted from tests/golden/day3.npz — if the "
                    "day cycle changed on purpose, regenerate the trace")


if __name__ == "__main__":
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    np.savez_compressed(GOLDEN, **golden_rollout())
    print(f"wrote {GOLDEN}:")
    for k, v in np.load(GOLDEN).items():
        print(f"  {k}: {v.shape} {v.dtype}")
