"""Joint spatio-temporal optimization (spatial.solve_joint) + the solver
layer it is assembled from.

Contracts under test:

* mobility=0 (static Python scalar) collapses to the EXACT legacy
  temporal graph — bitwise, kernel path included (the spatial analogue of
  the K=1 risk-ensemble contract).
* joint (weakly) dominates the sequential greedy-pre-shift + temporal
  solve on BOTH the nominal objective and its carbon term, for every
  mobility in the sweep (structural: best-of safeguard).
* the fused joint kernel step (Pallas interpreter on CPU) matches the jnp
  oracle, remainder tiles included.
* the spatial pre-shift's import cap is headroom- AND size-aware.
* solver.minimize_linear matches the independent numpy greedy oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver, spatial, vcc
from repro.kernels.vcc_pgd import kernel as kker
from repro.kernels.vcc_pgd import ref as kref
from repro.sim import MOBILITY_SWEEP

f32 = jnp.float32


# the ONE zonal recipe, shared with the sim_bench joint probe
_zonal_problem = vcc.synthetic_zonal_problem


# ------------------------------------------------- mobility=0 collapse

def test_mobility_zero_bitwise_identical_to_legacy_solve():
    """Acceptance contract: solve_joint(p, 0.0) IS solve_vcc(p), bitwise
    — jnp oracle and interpret-mode kernel both."""
    p = _zonal_problem()
    for kw in (dict(use_pallas=False), dict(interpret=True)):
        plain = vcc.solve_vcc(p, **kw)
        sol, tau_j, s = spatial.solve_joint(p, 0.0, **kw)
        for name in ("delta", "y", "vcc", "shaped", "mu", "objective"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sol, name)),
                np.asarray(getattr(plain, name)),
                err_msg=f"{name} ({kw})")
        np.testing.assert_array_equal(np.asarray(tau_j), np.asarray(p.tau))
        assert float(jnp.abs(s).max()) == 0.0


def test_traced_mobility_zero_pins_shift_to_zero():
    """Batched (traced) mobility=0 cannot statically collapse, but the
    bounds pin s to exactly zero through the joint graph."""
    p = _zonal_problem(n=6)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), p, p)
    sol, tau_j, s = spatial.solve_joint_batched(
        stacked, jnp.asarray([0.0, 0.4]), use_pallas=False)
    assert float(jnp.abs(s[0]).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(tau_j[0]), np.asarray(p.tau))
    assert float(jnp.abs(s[1]).sum()) > 0.0


# ------------------------------------------------- dominance (best-of)

def test_joint_dominates_sequential_on_mobility_sweep():
    """For every mobility in the sweep, the joint solution's carbon AND
    nominal objective are <= the sequential two-phase answer's, evaluated
    on the same model-consistent functions (structural via the best-of
    safeguard in solve_joint)."""
    p = _zonal_problem()
    for mob in MOBILITY_SWEEP:
        sol, tau_j, s = spatial.solve_joint(p, float(mob),
                                            use_pallas=False)
        tau_sh, _ = spatial.spatial_shift(p, mobility=float(mob))
        sol_seq = vcc.solve_vcc(dataclasses.replace(p, tau=tau_sh),
                                use_pallas=False)
        s0 = tau_sh - p.tau
        c_j = float(spatial.joint_carbon(p, sol.delta, s))
        c_q = float(spatial.joint_carbon(p, sol_seq.delta, s0))
        o_j = float(spatial.joint_objective(p, sol.delta, s))
        o_q = float(spatial.joint_objective(p, sol_seq.delta, s0))
        tol = 1e-5
        assert c_j <= c_q * (1 + tol) + tol, (mob, c_j, c_q)
        assert o_j <= o_q * (1 + tol) + tol, (mob, o_j, o_q)


def test_joint_strictly_improves_when_saturated():
    """On the saturated zonal fleet at high mobility the joint refinement
    must find strictly less carbon than the greedy pre-shift."""
    p = _zonal_problem(n=16, seed=7)
    sol, _, s = spatial.solve_joint(p, 0.6, use_pallas=False)
    tau_sh, _ = spatial.spatial_shift(p, mobility=0.6)
    sol_seq = vcc.solve_vcc(dataclasses.replace(p, tau=tau_sh),
                            use_pallas=False)
    c_j = float(spatial.joint_carbon(p, sol.delta, s))
    c_q = float(spatial.joint_carbon(p, sol_seq.delta, tau_sh - p.tau))
    assert c_j < c_q, (c_j, c_q)


def test_joint_solution_respects_constraints():
    """Joint delta conserves each cluster's day and respects the bounds
    recomputed at the SHIFTED budgets; s conserves the fleet."""
    p = _zonal_problem()
    sol, tau_j, s = spatial.solve_joint(p, 0.4, use_pallas=False)
    assert float(jnp.abs(s.sum())) < 1e-3 * float(p.tau.sum())
    lo_s, ub_s = spatial.shift_bounds(p, 0.4)
    assert bool(jnp.all(s >= lo_s - 1e-4))
    assert bool(jnp.all(s <= ub_s + 1e-4))
    lo, ub, feas = vcc.delta_bounds(dataclasses.replace(p, tau=tau_j))
    d = np.asarray(sol.delta)
    assert np.abs(d.sum(axis=1)).max() < 1e-3
    feas_np = np.asarray(feas)
    assert (d[feas_np] >= np.asarray(lo)[feas_np] - 1e-3).all()
    assert (d[feas_np] <= np.asarray(ub)[feas_np] + 1e-3).all()
    assert (d[~feas_np] == 0.0).all()


# ------------------------------------------------- kernel parity

def test_joint_step_interpret_kernel_matches_ref():
    """The fused joint step through the Pallas interpreter must match the
    jnp oracle, including remainder tiles (n not divisible by the tile)."""
    for n in (12, 7):
        p = _zonal_problem(n=n, seed=5)
        key = jax.random.PRNGKey(n)
        d = 0.1 * jax.random.normal(key, (n, 24))
        s = 0.2 * jax.random.normal(jax.random.fold_in(key, 1), (n, 1))
        tau = p.tau[:, None]
        price = jnp.full((n, 1), 0.05, f32)
        lr = jnp.full((n, 1), 0.01, f32)
        kw = dict(temp=10.0, lambda_e=0.3, drop_limit=float(p.drop_limit))
        d_r, g_r = kref.joint_step_arrays(
            d, s, p.eta, p.pi, p.pow_nom, tau, p.u_if, p.u_if_q, p.ratio,
            p.u_pow_cap[:, None], p.capacity[:, None], price, lr, **kw)
        d_k, g_k = kker.joint_step_pallas(
            d, s, p.eta, p.pi, p.pow_nom, tau, p.u_if, p.u_if_q, p.ratio,
            p.u_pow_cap[:, None], p.capacity[:, None], price, lr,
            tile=8, interpret=True, **kw)
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                                   rtol=1e-5, atol=1e-6, err_msg=f"n={n}")
        np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                                   rtol=1e-5, atol=1e-6, err_msg=f"n={n}")


def test_solve_joint_interpret_matches_ref():
    p = _zonal_problem(n=10, seed=4)
    ref, tau_r, s_r = spatial.solve_joint(p, 0.4, use_pallas=False)
    ker, tau_k, s_k = spatial.solve_joint(p, 0.4, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.delta), np.asarray(ref.delta),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ker.vcc), np.asarray(ref.vcc),
                               rtol=1e-5, atol=1e-4)


def test_solve_joint_jit_and_vmap():
    """jit and eager may legally pick different best-of branches when the
    joint and sequential candidates tie to float precision (different
    XLA fusion/FMA choices), so assert equal solution QUALITY, not
    bitwise equality."""
    p = _zonal_problem(n=6)
    sol_e, _, s_e = spatial.solve_joint(p, 0.3, use_pallas=False)
    sol_j, _, s_j = jax.jit(lambda q: spatial.solve_joint(
        q, 0.3, use_pallas=False))(p)
    np.testing.assert_allclose(
        float(spatial.joint_carbon(p, sol_j.delta, s_j)),
        float(spatial.joint_carbon(p, sol_e.delta, s_e)), rtol=1e-4)
    np.testing.assert_allclose(
        float(spatial.joint_objective(p, sol_j.delta, s_j)),
        float(spatial.joint_objective(p, sol_e.delta, s_e)), rtol=1e-4)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), p, p)
    solb, taub, sb = spatial.solve_joint_batched(stacked, 0.3,
                                                 use_pallas=False)
    assert solb.delta.shape == (2, 6, 24)
    assert sb.shape == (2, 6)


# ------------------------------------------------- engine integration

def test_joint_rollout_through_engine():
    """SimConfig(joint_spatial=True) runs the mobility sweep end to end:
    finite ledgers, and the mobility=0 row matches the sequential-path
    rollout of the same scenario (both graphs pin the shift to zero)."""
    from repro.sim import (SimConfig, build_batch, mobility_sweep_library,
                           rollout_batch)
    days, seeds = 2, [0]
    scens = mobility_sweep_library(days, mobilities=(0.0, 0.3))
    led = {}
    for joint in (True, False):
        cfg = SimConfig(n_clusters=4, n_campuses=2, n_zones=2,
                        pds_per_cluster=2, hist_days=10,
                        joint_spatial=joint)
        batch = build_batch(cfg, scens, seeds, days)
        _, led[joint], _ = rollout_batch(cfg, days)(batch)
    for b in (True, False):
        assert np.isfinite(np.asarray(led[b].carbon_kg)).all()
    # mobility=0 (batch row 0): joint graph == sequential graph to float
    # tolerance (different XLA programs, same math — s pinned to 0)
    np.testing.assert_allclose(np.asarray(led[True].carbon_kg[0]),
                               np.asarray(led[False].carbon_kg[0]),
                               rtol=1e-4)


def test_joint_with_ensemble_stage():
    """joint_spatial + n_members > 1 composes: the joint solve places
    budgets on the point forecast, the CVaR solve shapes at them."""
    from repro.sim import (SimConfig, build_batch, mobility_sweep_library,
                           rollout_batch)
    cfg = SimConfig(n_clusters=4, n_campuses=2, n_zones=2,
                    pds_per_cluster=2, hist_days=10, joint_spatial=True,
                    n_members=2)
    scens = mobility_sweep_library(1, mobilities=(0.3,))
    batch = build_batch(cfg, scens, [0], 1)
    _, led, _ = rollout_batch(cfg, 1)(batch)
    assert np.isfinite(np.asarray(led.carbon_kg)).all()


# ------------------------------------------------- spatial import cap

def test_import_cap_is_size_and_headroom_aware():
    """No cluster imports more than min(mobility * its own budget, its
    headroom) — the uniform fleet-average cap is gone."""
    n = 8
    rng = np.random.RandomState(0)
    H = 24
    capacity = jnp.asarray(8.0 + 4.0 * rng.rand(n), f32)
    u_if = jnp.asarray(2.0 + rng.rand(n, H), f32)
    # one tiny cluster (index 0): under the old uniform cap it could
    # import the fleet-average share; now its import is bounded by its
    # own mobility budget
    tau = jnp.asarray([0.5] + [20.0] * (n - 1), f32)
    eta = jnp.asarray(np.concatenate([[0.1], 2.0 + rng.rand(n - 1)])[:, None]
                      * np.ones((1, H)), f32)
    p = vcc.VCCProblem(
        eta=eta, u_if=u_if, u_if_q=u_if * 1.1, tau=tau,
        pow_nom=jnp.ones((n, H)) * 500.0, pi=jnp.ones((n, H)) * 300.0,
        u_pow_cap=capacity * 0.95, capacity=capacity,
        ratio=jnp.ones((n, H)) * 1.3,
        campus=jnp.zeros((n,), jnp.int32),
        campus_limit=jnp.asarray([1e9], f32))
    mob = 0.5
    tau2, _ = spatial.spatial_shift(p, mobility=mob)
    imported = np.asarray(tau2 - p.tau)
    lo, ub = spatial.shift_bounds(p, mob)
    assert (imported <= np.asarray(ub) + 1e-4).all()
    # the cheap tiny cluster is import-capped by its own size, not the
    # fleet average (old cap: mob * tau.sum()/n = 8.8 >> 0.25)
    assert imported[0] <= mob * float(tau[0]) + 1e-4
    # exports still bounded by the cluster's own mobility budget
    assert (-imported <= mob * np.asarray(tau) + 1e-4).all()


# ------------------------------------------------- solver layer oracle

def test_minimize_linear_matches_greedy_oracle():
    rng = np.random.RandomState(3)
    for _ in range(5):
        c = rng.randn(24)
        lo = -rng.rand(24)
        ub = rng.rand(24)
        got = np.asarray(solver.minimize_linear(
            jnp.asarray(c, f32)[None], jnp.asarray(lo, f32)[None],
            jnp.asarray(ub, f32)[None])[0])
        want = vcc.greedy_linear_reference(c, lo, ub)
        # same optimal value (the argmin may differ on ties)
        assert float((c * got).sum()) <= float((c * want).sum()) + 1e-4
        np.testing.assert_allclose(got.sum(), 0.0, atol=1e-5)
        assert (got >= lo - 1e-6).all() and (got <= ub + 1e-6).all()


def test_dual_ascent_carries_pytree_state():
    """solver.dual_ascent accepts an arbitrary pytree for x (the joint
    solve carries (delta, s))."""
    def inner(x, mu):
        a, b = x
        return (a + mu, b - 1.0)

    def dual_update(x, mu):
        return mu + 1.0

    (a, b), mu = solver.dual_ascent(inner, dual_update,
                                    (jnp.zeros(()), jnp.zeros(())),
                                    jnp.zeros(()), 3)
    assert float(mu) == 3.0 and float(a) == 3.0 and float(b) == -3.0
