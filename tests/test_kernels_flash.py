"""Pallas flash-attention kernel vs exact oracle: shape/dtype/feature
sweep, interpret mode (deliverable c)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import (attention_chunked,
                                               attention_reference)

CASES = [
    # B, Sq, N, K, H, causal, window, softcap, dtype
    (2, 256, 4, 2, 64, True, None, None, jnp.float32),
    (1, 200, 8, 8, 32, True, None, 50.0, jnp.float32),
    (2, 128, 4, 1, 64, True, 64, None, jnp.float32),
    (1, 256, 2, 2, 128, False, None, None, jnp.float32),
    (1, 192, 6, 3, 64, True, None, None, jnp.float32),
    (2, 128, 4, 2, 64, True, None, None, jnp.bfloat16),
    (1, 320, 4, 4, 96, True, 128, 30.0, jnp.float32),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_vs_reference(case):
    B, Sq, N, K, H, causal, window, softcap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, N, H)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sq, K, H)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sq, K, H)).astype(dtype)
    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         softcap=softcap, qb=64, kb=64, interpret=True)
    o2 = attention_reference(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.abs(o1.astype(jnp.float32)
                        - o2.astype(jnp.float32)).max())
    assert err < tol, (case, err)


def test_chunked_equals_reference():
    """The production XLA path is numerically identical to the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 300, 4, 32))
    k = jax.random.normal(ks[1], (2, 300, 2, 32))
    v = jax.random.normal(ks[2], (2, 300, 2, 32))
    o1 = attention_chunked(q, k, v, causal=True, q_chunk=128)
    o2 = attention_reference(q, k, v, causal=True)
    assert float(jnp.abs(o1 - o2).max()) < 1e-5


def test_decode_length_masking():
    """Cache-length masking: positions >= length must not contribute."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, N, H = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (B, 1, N, H))
    k = jax.random.normal(ks[1], (B, S, N, H))
    v = jax.random.normal(ks[2], (B, S, N, H))
    pos = 17
    o1 = attention_reference(q, k, v, causal=True, q_offset=pos,
                             length=pos + 1)
    k2 = k.at[:, pos + 1:].set(999.0)       # garbage beyond length
    v2 = v.at[:, pos + 1:].set(999.0)
    o2 = attention_reference(q, k2, v2, causal=True, q_offset=pos,
                             length=pos + 1)
    assert float(jnp.abs(o1 - o2).max()) < 1e-6
