"""GLA (linear_scan) kernel + chunked ref vs sequential oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.linear_scan.kernel import gla_pallas
from repro.kernels.linear_scan.ref import gla_chunked, gla_naive, gla_step

CASES = [
    # B, S, H, K, V, mode, chunk
    (2, 64, 2, 16, 8, "scalar", 16),
    (1, 96, 3, 8, 16, "vector", 32),
    (2, 64, 2, 8, 8, "rwkv", 16),
    (1, 37, 1, 4, 4, "rwkv", 8),        # ragged length
    (2, 128, 2, 32, 16, "scalar", 64),
]


def _inputs(case, key):
    B, S, H, K, V, mode, chunk = case
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, V))
    if mode == "scalar":
        ld = -jnp.abs(jax.random.normal(ks[3], (B, S, H))) * 0.7
        return q, k, v, ld, None, False
    ld = -jnp.abs(jax.random.normal(ks[3], (B, S, H, K))) * 3.0
    if mode == "vector":
        return q, k, v, ld, None, False
    u = jax.random.normal(ks[4], (H, K))
    return q, k, v, ld, u, True


@pytest.mark.parametrize("case", CASES)
def test_chunked_vs_naive(case):
    q, k, v, ld, u, strict = _inputs(case, jax.random.PRNGKey(0))
    o1, h1 = gla_chunked(q, k, v, ld, bonus=u, strict=strict,
                         chunk=case[-1])
    o2, h2 = gla_naive(q, k, v, ld, bonus=u, strict=strict)
    # fp32 accumulation-order tolerance scales with K and S
    tol = 2e-4
    assert float(jnp.abs(o1 - o2).max()) < tol, case
    assert float(jnp.abs(h1 - h2).max()) < tol, case


@pytest.mark.parametrize("case", CASES)
def test_pallas_vs_naive(case):
    q, k, v, ld, u, strict = _inputs(case, jax.random.PRNGKey(1))
    o1, h1 = gla_pallas(q, k, v, ld, bonus=u, strict=strict, chunk=case[-1],
                        interpret=True)
    o2, h2 = gla_naive(q, k, v, ld, bonus=u, strict=strict)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4, case
    assert float(jnp.abs(h1 - h2).max()) < 1e-4, case


def test_chunk_size_invariance():
    """Output must not depend on the chunk size."""
    q, k, v, ld, u, strict = _inputs((2, 96, 2, 8, 8, "rwkv", 8),
                                     jax.random.PRNGKey(2))
    outs = [gla_chunked(q, k, v, ld, bonus=u, strict=strict, chunk=c)[0]
            for c in (8, 16, 32, 96)]
    for o in outs[1:]:
        # fp32 accumulation-order tolerance (matches test_chunked_vs_naive)
        assert float(jnp.abs(o - outs[0]).max()) < 2e-4


def test_step_matches_sequence():
    """Streaming gla_step over a sequence == batch gla_naive."""
    q, k, v, ld, u, strict = _inputs((1, 16, 2, 8, 8, "rwkv", 8),
                                     jax.random.PRNGKey(3))
    o_ref, _ = gla_naive(q, k, v, ld, bonus=u, strict=strict)
    B, S, H, K = q.shape
    h = jnp.zeros((B, H, K, v.shape[-1]))
    for t in range(S):
        o, h = gla_step(q[:, t], k[:, t], v[:, t], ld[:, t], h, bonus=u,
                        strict=strict)
        assert float(jnp.abs(o - o_ref[:, t]).max()) < 1e-5, t
