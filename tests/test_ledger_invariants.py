"""Ledger conservation invariants (PR 3 satellite).

Flexible work is fluid: whatever arrives is either served or carried in
the queue — nothing may silently vanish, per cluster, per rollout, for
EVERY scenario in the default library and the risk sweep. This catches
silent work loss in risk-constrained runs (a too-tight VCC must delay
work, never delete it), in both the shaped run and the unshaped
counterfactual the engine advances in the same trace.
"""
import jax
import numpy as np

from repro.sim import (SimConfig, build_batch, default_library, make_init,
                       risk_sweep_library, rollout_batch)

DAYS = 4
CFG = SimConfig(n_clusters=6, n_campuses=2, n_zones=2, pds_per_cluster=2,
                hist_days=14)


def _conservation(cfg, scenarios, seeds):
    batch = build_batch(cfg, scenarios, seeds, DAYS)
    state, led, _ = rollout_batch(cfg, DAYS)(batch)
    # rollout_batch re-inits internally; recompute the burned-in starting
    # queues to anchor the balance (same pure init, bitwise identical)
    state0 = jax.jit(jax.vmap(make_init(cfg)))(batch)
    names = [s.name for s in scenarios for _ in seeds]
    for b, name in enumerate(names):
        for tag, served, q0, q1 in (
                ("shaped", led.served[b], state0.queue[b], state.queue[b]),
                ("counterfactual", led.cf_served[b], state0.cf_queue[b],
                 state.cf_queue[b])):
            arrived = np.asarray(led.arrived[b], np.float64)
            balance = np.asarray(q0, np.float64) + arrived
            spent = np.asarray(served, np.float64) \
                + np.asarray(q1, np.float64)
            np.testing.assert_allclose(
                spent, balance, rtol=1e-4, atol=1e-3,
                err_msg=f"{tag} flex CPU-h not conserved in '{name}': "
                        "served + carried queue != arrived + initial "
                        "queue (work was silently lost or created)")


def test_flex_work_conserved_across_default_library():
    _conservation(CFG, default_library(DAYS), [0])


def test_flex_work_conserved_risk_sweep_ensemble():
    """Risk-constrained (CVaR, K=4) runs must also conserve work — a
    risk-averse VCC delays flexible CPU-h, it must never lose them."""
    cfg = SimConfig(n_clusters=6, n_campuses=2, n_zones=2,
                    pds_per_cluster=2, hist_days=14, n_members=4)
    _conservation(cfg, risk_sweep_library(DAYS), [0])


def test_arrivals_match_counterfactual():
    """Shaped and counterfactual runs see the same demand by construction
    (the ledger's arrived is the single source)."""
    batch = build_batch(CFG, default_library(DAYS)[:3], [0, 1], DAYS)
    _, led, _ = rollout_batch(CFG, DAYS)(batch)
    assert np.all(np.asarray(led.arrived) >= 0.0)
    assert np.all(np.asarray(led.served) >= 0.0)
    assert np.all(np.asarray(led.cf_served) >= 0.0)
    # served can never exceed what arrived plus what was queued at start
    state0 = jax.jit(jax.vmap(make_init(CFG)))(batch)
    slack = np.asarray(led.arrived) + np.asarray(state0.queue) \
        - np.asarray(led.served)
    assert slack.min() > -1e-3
