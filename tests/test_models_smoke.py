"""Per-arch smoke: reduced same-family config, one forward/train step on
CPU, asserting output shapes + finite values (deliverable f)."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.training import make_train_step

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, key, B=2, S=24):
    b = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["vision_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        b["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_loss(name):
    cfg = get_arch(name).smoke.replace(dtype="float32", remat="none")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    loss, metrics = model.loss(params, _batch(cfg, key))
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("name", ["yi-6b", "deepseek-moe-16b", "zamba2-7b",
                                  "rwkv6-7b", "whisper-base"])
def test_smoke_train_step_improves(name):
    cfg = get_arch(name).smoke.replace(dtype="float32", remat="none")
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt_cfg = AdamWConfig(peak_lr=5e-3, warmup_steps=1, decay_steps=100)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    batch = _batch(cfg, key)
    losses = []
    for i in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), (name, i)
    assert losses[-1] < losses[0], (name, losses)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_grads_finite(name):
    cfg = get_arch(name).smoke.replace(dtype="float32", remat="none")
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    g = jax.grad(lambda p: model.loss(p, _batch(cfg, key))[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf)))
