"""Intra-day MPC recourse layer (core/mpc.py + the mpc=True day step).

Contract under test:

  * ``StageConfig.mpc`` / ``SimConfig.mpc`` default OFF — the closed
    loop is opt-in; the mpc=False day step never imports the recourse
    path (the byte-identical-HLO collapse certificate itself lives in
    benchmarks/sim_bench.py where the verbatim pre-MPC ``run_day`` is
    monkeypatched in).
  * ``vcc.solve_vcc_suffix`` pins elapsed hours at the committed
    deviations, keeps the suffix inside the day-ahead box and preserves
    whole-day conservation; infeasible clusters keep their plan.
  * ``mpc.mpc_day`` with the recourse gate closed reproduces the
    open-loop ``admission.run_day`` BITWISE (shared admission_tick /
    finalize_day — the controller cannot fork from open-loop semantics).
  * With the gate open and a forecast-busting intensity divergence the
    trigger actually fires and the enforced curve departs from the
    00:00 plan.
  * An mpc=True rollout runs under jit+vmap end to end (with streaming
    and telemetry stacked on) and emits sane recourse diagnostics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admission, mpc, stages, vcc
from repro.core.admission import hour_sum
from repro.sim import (SimConfig, build_batch, forecast_bust_library,
                       rollout_batch)

f32 = jnp.float32


def _power_fn(u):
    return 100.0 + 300.0 * u


def _day_inputs(n, seed=0):
    """Synthetic realized day: (u_if, arrivals, ratio, intensity)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    u_if = 0.4 + 0.05 * jax.random.normal(ks[0], (n, 24))
    arrivals = 0.15 + 0.1 * jax.random.uniform(ks[1], (n, 24))
    ratio = jnp.full((n, 24), 1.3)
    intensity = jnp.abs(0.3 + 0.2 * jax.random.normal(ks[3], (n, 24)))
    return u_if, arrivals, ratio, intensity


def test_mpc_defaults_off():
    assert stages.StageConfig().mpc is False
    assert SimConfig().mpc is False
    # and the engine threads the flag through
    assert SimConfig(mpc=True).stage_config().mpc is True


def test_suffix_solve_pins_elapsed_and_conserves():
    p = vcc.synthetic_problem(8, seed=3, n_campuses=2)
    sol = vcc.solve_vcc(p, use_pallas=False)
    hour = 9
    # committed prefix: the day-ahead plan's own deviations (conserving)
    sfx = vcc.solve_vcc_suffix(p, sol.delta, sol.mu, hour,
                               use_pallas=False)
    feas = np.asarray(sfx.shaped)
    assert feas.any()
    lo, ub, _ = vcc.delta_bounds(p)
    d = np.asarray(sfx.delta)
    # elapsed hours pinned bitwise at the committed deviations
    np.testing.assert_array_equal(d[feas][:, :hour],
                                  np.asarray(sol.delta)[feas][:, :hour])
    # suffix stays inside the day-ahead box, whole day conserves
    assert (d[feas][:, hour:] >= np.asarray(lo)[feas][:, hour:] - 1e-5) \
        .all()
    assert (d[feas][:, hour:] <= np.asarray(ub)[feas][:, hour:] + 1e-5) \
        .all()
    np.testing.assert_allclose(np.asarray(hour_sum(sfx.delta))[feas], 0.0,
                               atol=5e-4)


def test_suffix_infeasible_cluster_keeps_plan():
    """A realized prefix that spent more than the whole budget cannot be
    conserved by any suffix — the cluster must keep its current plan
    (lo == ub == committed) and fall back to the unshaped curve."""
    p = vcc.synthetic_problem(4, seed=5, n_campuses=2)
    sol = vcc.solve_vcc(p, use_pallas=False)
    hour = 12
    # force cluster 0's committed prefix to +24 per hour: the remaining
    # hours would need sum(delta) = -288, far below 24 * drop_limit
    bad = sol.delta.at[0, :hour].set(24.0)
    sfx = vcc.solve_vcc_suffix(p, bad, sol.mu, hour, use_pallas=False)
    assert not bool(sfx.shaped[0])
    np.testing.assert_array_equal(np.asarray(sfx.delta)[0],
                                  np.asarray(bad)[0])
    np.testing.assert_allclose(np.asarray(sfx.vcc)[0],
                               float(p.capacity[0]), rtol=1e-6)


def test_mpc_day_gate_closed_is_open_loop_bitwise():
    """gate=False every cluster -> the enforced curve is the unshaped
    10x-capacity curve every hour and no re-solve is ever accepted: the
    DayResult must equal ``admission.run_day`` on that same curve
    BITWISE."""
    n = 6
    p = vcc.synthetic_problem(n, seed=7, n_campuses=2)
    sol = vcc.solve_vcc(p, use_pallas=False)
    u_if, arrivals, ratio, intensity = _day_inputs(n)
    gate = jnp.zeros((n,), bool)
    queue0 = jnp.asarray(np.linspace(0.0, 0.4, n), f32)
    res, vcc_real, acc, diag = mpc.mpc_day(
        p, sol, p.tau, gate, p.capacity, u_if, arrivals, ratio, queue0,
        _power_fn, intensity, use_pallas=False)
    open_curve = jnp.broadcast_to(p.capacity[:, None] * 10.0, (n, 24))
    ref = admission.run_day(open_curve, u_if, arrivals, ratio, p.capacity,
                            queue0, _power_fn, intensity)
    for field in admission.DayResult.__dataclass_fields__:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, field)),
            np.asarray(getattr(ref, field)), err_msg=field)
    np.testing.assert_array_equal(np.asarray(vcc_real),
                                  np.asarray(open_curve))
    # no recourse accepted, accumulator saw all 24 hours
    assert float(diag.recourse_frac.max()) == 0.0
    assert int(acc.hour) == 24
    np.testing.assert_array_equal(np.asarray(acc.flex_daily),
                                  np.asarray(res.served))


def test_mpc_day_triggers_on_intensity_divergence():
    """A 2.5x realized-vs-forecast intensity spike trips the eta trigger:
    shaped clusters re-plan and the enforced curve departs from the
    00:00 plan's curve on later hours."""
    n = 6
    p = vcc.synthetic_problem(n, seed=11, n_campuses=2)
    sol = vcc.solve_vcc(p, use_pallas=False)
    u_if = p.u_if                       # actuals match forecast (no MAPE)
    arrivals = jnp.full((n, 24), 0.1)
    ratio = p.ratio
    intensity = p.eta * 2.5             # forecast-busting spike
    gate = sol.shaped
    assert bool(gate.any())
    queue0 = jnp.zeros((n,), f32)
    res, vcc_real, acc, diag = mpc.mpc_day(
        p, sol, p.tau, gate, p.capacity, u_if, arrivals, ratio, queue0,
        _power_fn, intensity, use_pallas=False)
    g = np.asarray(gate)
    assert float(np.asarray(diag.recourse_frac)[g].max()) > 0.0
    assert float(np.asarray(diag.recourse_depth)[g].max()) > 0.0
    plan_curve = np.asarray(mpc.gated_curve(p, sol.delta, p.tau, gate,
                                            p.capacity))
    assert np.abs(np.asarray(vcc_real)[g] - plan_curve[g]).max() > 1e-4
    # hour 0 is always enforced from the 00:00 plan (recourse starts
    # after the first observation)
    np.testing.assert_allclose(np.asarray(vcc_real)[:, 0],
                               plan_curve[:, 0], rtol=1e-6)


def test_mpc_rollout_batch_runs_with_streaming_and_telemetry():
    cfg = SimConfig(n_clusters=4, n_campuses=2, n_zones=2,
                    pds_per_cluster=2, hist_days=14, streaming=True,
                    telemetry=True, mpc=True)
    days = 2
    scens = forecast_bust_library(days=days)[:1]
    params = build_batch(cfg, scens, seeds=[0], days=days)
    from repro.sim import make_init
    queue_init = jax.vmap(jax.jit(make_init(cfg)))(params).queue
    state, led, traj = rollout_batch(cfg, days)(params)
    assert np.isfinite(np.asarray(led.carbon_kg)).all()
    t = traj["telemetry"]
    frac = np.asarray(t.mpc_recourse_frac)
    depth = np.asarray(t.mpc_recourse_depth)
    assert frac.shape == (1, days, cfg.n_clusters)
    assert ((frac >= 0.0) & (frac <= 1.0)).all()
    assert (depth >= 0.0).all()
    # queue conservation survives the closed loop: burned-in backlog +
    # arrivals = served + final backlog
    lhs = float(queue_init.sum() + np.asarray(led.arrived).sum())
    rhs = float(np.asarray(led.served).sum()
                + np.asarray(state.queue).sum())
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_mpc_off_rollout_has_zero_recourse_telemetry():
    """telemetry=True, mpc=False: the record carries the recourse gauges
    as all-zeros placeholders (TRACE_FIELDS is flag-invariant)."""
    cfg = SimConfig(n_clusters=4, n_campuses=2, n_zones=2,
                    pds_per_cluster=2, hist_days=14, telemetry=True)
    days = 2
    scens = forecast_bust_library(days=days)[:1]
    params = build_batch(cfg, scens, seeds=[0], days=days)
    _, _, traj = rollout_batch(cfg, days)(params)
    assert float(np.abs(np.asarray(
        traj["telemetry"].mpc_recourse_frac)).max()) == 0.0
