"""Hypothesis property tests for the intra-day MPC recourse layer.

Two contracts the closed loop leans on:

  * **Hour-grain == day-grain predictor advancement** — chaining 24
    ``stats.hour_update`` calls and closing the day with
    ``stats.hour_finalize`` is BITWISE the daily batch
    ``stats.predictor_update`` on the assembled arrays: the accumulator
    scatters columns in hour order and accumulates daily totals by the
    same ordered adds as ``admission.hour_sum``, so the streaming carry
    cannot drift depending on which grain observed the day.
  * **Suffix re-solve feasibility** — for ANY committed prefix and
    re-solve hour, ``vcc.solve_vcc_suffix`` keeps elapsed hours pinned,
    keeps the remaining hours inside the day-ahead box, and satisfies
    the tightened suffix conservation (sum of the whole day ~ 0) on
    every cluster it reports ``shaped``; clusters whose prefix cannot
    be conserved keep their plan exactly.

Skips as a unit when the `hypothesis` capability is absent (the CI
workflow installs it and runs these under the fixed-seed `ci` profile).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="capability check: the `hypothesis` package is not importable "
           "here; CI installs it (see .github/workflows/ci.yml) and runs "
           "these property tests under the fixed-seed 'ci' profile")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import stats, vcc  # noqa: E402
from repro.core.admission import hour_sum  # noqa: E402

SET = dict(max_examples=15, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])

N, HIST, GAMMA = 3, 14, 0.05


def _predictor(seed=0):
    """A PredictorState warm-started from a synthetic rescan window."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 7)
    u = jax.random.uniform
    hist_uif = 0.3 + 0.2 * u(ks[0], (N, HIST, 24))
    hist_flex = 2.0 + u(ks[1], (N, HIST))
    hist_res = 8.0 + u(ks[2], (N, HIST))
    hist_usage = 0.5 + 0.3 * u(ks[3], (N, HIST, 24))
    hist_resv = hist_usage * 1.3
    hist_tr_pred = hist_res * (1.0 + 0.05 * u(ks[4], (N, HIST)))
    hist_uif_pred = hist_uif * (1.0 + 0.05 * u(ks[5], (N, HIST, 24)))
    day = jnp.asarray(HIST, jnp.int32)
    return stats.init_predictor(hist_uif, hist_flex, hist_res, hist_usage,
                                hist_resv, hist_tr_pred, hist_uif_pred,
                                day, GAMMA), day


@given(
    u_if=hnp.arrays(np.float32, (N, 24),
                    elements=st.floats(0.01, 2.0, width=32)),
    use_flex=hnp.arrays(np.float32, (N, 24),
                        elements=st.floats(0.0, 1.0, width=32)),
    ratio=hnp.arrays(np.float32, (N, 24),
                     elements=st.floats(1.0, 2.0, width=32)),
)
@settings(**SET)
def test_hourly_chain_equals_daily_batch_update_bitwise(u_if, use_flex,
                                                        ratio):
    pred, day = _predictor()
    fc = stats.streaming_forecast(pred, day, GAMMA)
    u_if, use_flex, ratio = map(jnp.asarray, (u_if, use_flex, ratio))

    acc = stats.hour_accum_init(N)
    upd = jax.jit(stats.hour_update)
    for h in range(24):
        acc = upd(acc, jnp.asarray(h, jnp.int32), u_if[:, h],
                  use_flex[:, h], ratio[:, h])
    chained = stats.hour_finalize(pred, acc, fc, day, GAMMA)

    usage = u_if + use_flex
    res = usage * ratio
    batch = stats.predictor_update(pred, fc, day, GAMMA, u_if,
                                   hour_sum(use_flex), hour_sum(res),
                                   usage, res)
    for name, a, b in zip(chained._fields, chained, batch):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


@given(
    hour=st.integers(1, 23),
    jitter=hnp.arrays(np.float32, (6, 24),
                      elements=st.floats(-0.3, 0.3, width=32)),
    seed=st.integers(0, 3),
)
@settings(**SET)
def test_suffix_resolve_satisfies_tightened_conservation(hour, jitter,
                                                         seed):
    p = vcc.synthetic_problem(6, seed=seed, n_campuses=2)
    sol = vcc.solve_vcc(p, inner_iters=20, outer_iters=5,
                        use_pallas=False)
    lo, ub, _ = vcc.delta_bounds(p)
    # committed prefix: the plan perturbed inside the day-ahead box (a
    # realized prefix need not conserve — that is the point of recourse)
    committed = jnp.clip(sol.delta + jnp.asarray(jitter), lo, ub)
    sfx = vcc.solve_vcc_suffix(p, committed, sol.mu, hour,
                               use_pallas=False)
    d = np.asarray(sfx.delta)
    feas = np.asarray(sfx.shaped)
    # elapsed hours pinned bitwise, feasible or not
    np.testing.assert_array_equal(d[:, :hour],
                                  np.asarray(committed)[:, :hour])
    if feas.any():
        # suffix inside the day-ahead box ...
        assert (d[feas][:, hour:]
                >= np.asarray(lo)[feas][:, hour:] - 1e-5).all()
        assert (d[feas][:, hour:]
                <= np.asarray(ub)[feas][:, hour:] + 1e-5).all()
        # ... and the tightened conservation holds: suffix sum cancels
        # the committed prefix, i.e. the whole day sums to ~0
        np.testing.assert_allclose(np.asarray(hour_sum(sfx.delta))[feas],
                                   0.0, atol=1e-3)
    if (~feas).any():
        # infeasible clusters keep their plan exactly and fall back to
        # the unshaped curve
        np.testing.assert_array_equal(d[~feas],
                                      np.asarray(committed)[~feas])
        np.testing.assert_allclose(
            np.asarray(sfx.vcc)[~feas],
            np.broadcast_to(np.asarray(p.capacity)[~feas, None],
                            d[~feas].shape), rtol=1e-6)
