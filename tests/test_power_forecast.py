"""Power models (paper §III-A: MAPE < 5%) and day-ahead forecasting
(§III-B): EWMA pipeline, ratio model, quantiles, eq. (3) inflation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forecast, power


def test_pd_fit_mape_under_5pct():
    key = jax.random.PRNGKey(0)
    n_pd, t = 32, 24 * 28
    truth = power.PDTruth(
        idle_kw=60 + 40 * jax.random.uniform(jax.random.fold_in(key, 1),
                                             (n_pd,)),
        slope_kw=250 + 150 * jax.random.uniform(jax.random.fold_in(key, 2),
                                                (n_pd,)),
        curve=0.8 + 0.5 * jax.random.uniform(jax.random.fold_in(key, 3),
                                             (n_pd,)))
    cpu = 0.2 + 0.6 * jax.random.uniform(jax.random.fold_in(key, 4),
                                         (n_pd, t))
    pw = power.simulate_pd_power(jax.random.fold_in(key, 5), truth, cpu)
    coef, breaks = power.fit_pd_models(cpu, pw)
    mapes = np.asarray(power.daily_mape_b(coef, breaks, cpu, pw))
    # paper: daily MAPE < 5% for > 95% of PDs
    assert (mapes < 0.05).mean() > 0.95, mapes.max()


def test_slope_is_derivative():
    cpu = jnp.linspace(0.05, 0.95, 500)
    pw = 100 + 300 * cpu ** 1.2
    coef, breaks = power.fit_pd_model(cpu, pw)
    u = jnp.asarray([0.3, 0.6, 0.8])
    eps = 1e-3
    fd = (power.pd_power(coef, breaks, u + eps)
          - power.pd_power(coef, breaks, u - eps)) / (2 * eps)
    sl = power.pd_slope(coef, breaks, u)
    np.testing.assert_allclose(np.asarray(fd), np.asarray(sl), rtol=1e-2)


def test_usage_fractions_near_constant():
    key = jax.random.PRNGKey(2)
    base = jnp.asarray([0.4, 0.3, 0.2, 0.1])[:, None]
    usage = base * (5.0 + jnp.sin(jnp.arange(200.0))[None]) \
        * (1 + 0.01 * jax.random.normal(key, (4, 200)))
    lam = power.usage_fractions(usage)
    np.testing.assert_allclose(np.asarray(lam), np.asarray(base[:, 0]),
                               atol=0.01)


def _history(days=35, seed=0):
    rng = np.random.RandomState(seed)
    hours = np.arange(24)
    prof = 1 + 0.3 * np.exp(-0.5 * ((hours - 14) / 4.0) ** 2)
    hist = []
    for d in range(days):
        wk = 1 + 0.1 * np.cos(2 * np.pi * (d % 7) / 7)
        hist.append(5.0 * prof * wk * (1 + 0.03 * rng.randn(24)))
    return jnp.asarray(np.stack(hist))


def test_inflexible_forecast_accuracy():
    hist = _history()
    pred = forecast.forecast_inflexible(hist[:-1], jnp.asarray(34 % 7))
    ape = np.abs(np.asarray(pred) - np.asarray(hist[-1])) \
        / np.asarray(hist[-1])
    assert np.median(ape) < 0.10        # paper Fig 7: median < 10%


def test_ratio_model_monotone_decreasing():
    rng = np.random.RandomState(0)
    usage = jnp.asarray(np.exp(rng.uniform(0, 3, size=500)))
    res = usage * (1.1 + 0.5 / jnp.sqrt(usage))
    a, b = forecast.fit_ratio_model(usage, res)
    r_small = forecast.ratio_at(a, b, jnp.asarray(1.0))
    r_big = forecast.ratio_at(a, b, jnp.asarray(20.0))
    assert float(r_big) <= float(r_small)
    assert float(r_big) >= 1.0          # ratio >= 1 by construction


def test_alpha_solves_eq3():
    """Plugging alpha back into eq. (3) must reproduce Theta."""
    key = jax.random.PRNGKey(3)
    uif = 4.0 + jax.random.uniform(key, (24,))
    tuf = jnp.asarray(30.0)
    a, b = jnp.asarray(1.4), jnp.asarray(-0.05)
    theta = jnp.asarray(230.0)
    alpha = forecast.alpha_inflation(theta, uif, tuf, a, b)
    u_nom = uif + tuf / 24.0
    r = forecast.ratio_at(a, b, u_nom)
    lhs = jnp.sum((uif + alpha * tuf / 24.0) * r)
    # exact unless alpha hit its [0.5, 4] clip
    if 0.5 < float(alpha) < 4.0:
        np.testing.assert_allclose(float(lhs), float(theta), rtol=1e-4)


def test_deviation_corrector_unbiased_on_periodic_series():
    """Regression for the deviation-corrector bug: the coef used to be
    fit against a CONSTANT weekly level, so a purely periodic series
    (zero true deviations) leaked its day-of-week pattern into the
    'deviations' and produced a spurious correction. Fit against the
    dow-factored weekly predictions, an exactly periodic history must
    forecast (close to) exactly."""
    pattern = np.asarray([1.2, 1.1, 1.0, 0.9, 0.8, 1.05, 0.95])
    days = 35
    daily = jnp.asarray(10.0 * pattern[np.arange(days) % 7], jnp.float32)
    hours = 1.0 + 0.3 * np.sin(np.arange(24) / 24.0 * 2 * np.pi)
    hourly = jnp.asarray(
        10.0 * pattern[np.arange(days) % 7][:, None] * hours[None],
        jnp.float32)
    for dow_next in range(7):
        hist = daily[:days - 7 + dow_next]
        truth = float(daily[days - 7 + dow_next])
        pred = float(forecast.forecast_daily_total(
            hist, jnp.asarray(hist.shape[0] % 7)))
        assert abs(pred - truth) / truth < 1e-3, (dow_next, pred, truth)
        hist_h = hourly[:days - 7 + dow_next]
        pred_h = forecast.forecast_inflexible(
            hist_h, jnp.asarray(hist_h.shape[0] % 7))
        ape = np.abs(np.asarray(pred_h)
                     - np.asarray(hourly[days - 7 + dow_next])) \
            / np.asarray(hourly[days - 7 + dow_next])
        assert ape.max() < 1e-3, (dow_next, ape.max())


def test_calibrate_half_lives_vectorized_matches_loop():
    """The single vmapped+jitted grid evaluation must select the same
    half-lives as the legacy per-combo Python loop (fixed seed)."""
    hist = _history(days=42, seed=3)
    grid = (0.25, 1.0, 4.0)
    got = forecast.calibrate_half_lives(hist, grid=grid)
    want = forecast.calibrate_half_lives_loop(hist, grid=grid)
    assert got == want, (got, want)


def test_theta_is_97th_quantile_requirement():
    preds = jnp.full((90,), 100.0)
    actuals = jnp.asarray(100.0 + np.random.RandomState(0).randn(90) * 5)
    q = forecast.relative_error_quantile(preds, actuals, 0.97)
    theta = forecast.theta_requirement(jnp.asarray(100.0), q)
    # Theta must cover ~97% of historical outcomes
    covered = (np.asarray(actuals) <= float(theta)).mean()
    assert covered >= 0.95
