"""Hypothesis property tests on system invariants (deliverable c).

This module (and its siblings test_forecast_properties.py) skips AS A
UNIT where the `hypothesis` package is not importable — a concrete
capability check, not a bare skip: the bare-metal image pins only the jax
toolchain, while the CI workflow installs hypothesis and runs these under
the fixed-seed "ci" profile registered in conftest.py, so the properties
are exercised on every push even when local environments lack the
package. Deterministic (non-hypothesis) coverage of the same subsystems
lives in test_risk.py / test_vcc_opt.py / test_ledger_invariants.py.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="capability check: the `hypothesis` package is not importable "
           "here; CI installs it (see .github/workflows/ci.yml) and runs "
           "these property tests under the fixed-seed 'ci' profile")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.vcc import project_conservation
from repro.kernels.linear_scan.ref import gla_chunked, gla_naive

SET = dict(max_examples=25, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(
    z=hnp.arrays(np.float32, (3, 24),
                 elements=st.floats(-5, 5, width=32)),
    width=st.floats(0.2, 3.0),
)
@settings(**SET)
def test_projection_properties(z, width):
    """Projection onto {sum=0} ∩ [lo, ub]: feasibility + idempotence."""
    lo = np.full((3, 24), -1.0, np.float32)
    ub = np.full((3, 24), width, np.float32)
    p = project_conservation(jnp.asarray(z), jnp.asarray(lo),
                             jnp.asarray(ub), iters=60)
    assert np.all(np.asarray(p) >= lo - 1e-4)
    assert np.all(np.asarray(p) <= ub + 1e-4)
    assert np.abs(np.asarray(p.sum(1))).max() < 1e-3
    p2 = project_conservation(p, jnp.asarray(lo), jnp.asarray(ub), iters=60)
    assert np.abs(np.asarray(p2 - p)).max() < 1e-3


@given(
    seed=st.integers(0, 2**16),
    s=st.integers(5, 60),
    chunk=st.sampled_from([4, 8, 16, 32]),
    strict=st.booleans(),
)
@settings(**SET)
def test_gla_chunk_invariance(seed, s, chunk, strict):
    """Chunked GLA == sequential recurrence for any chunking."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B, H, K, V = 1, 2, 4, 4
    q = jax.random.normal(ks[0], (B, s, H, K))
    k = jax.random.normal(ks[1], (B, s, H, K))
    v = jax.random.normal(ks[2], (B, s, H, V))
    ld = -jnp.abs(jax.random.normal(ks[3], (B, s, H, K))) * 2.0
    o1, h1 = gla_chunked(q, k, v, ld, strict=strict, chunk=chunk)
    o2, h2 = gla_naive(q, k, v, ld, strict=strict)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4
    assert float(jnp.abs(h1 - h2).max()) < 1e-4


@given(seed=st.integers(0, 2**16))
@settings(**SET)
def test_carbon_intensity_positive_bounded(seed):
    from repro.core import carbon
    zone = carbon.default_zones(4)[seed % 4]
    ci = carbon.simulate_zone(jax.random.PRNGKey(seed), zone, 3)
    arr = np.asarray(ci)
    assert arr.shape == (3, 24)
    assert np.all(arr > 0)
    assert np.all(arr < 1.2)           # below pure-coal intensity


@given(
    seed=st.integers(0, 2**16),
    scale=st.floats(0.1, 10.0),
)
@settings(**SET)
def test_compression_error_feedback_unbiased(seed, scale):
    """Over repeated steps with constant gradient g, the error-feedback
    compressor's cumulative output converges to the true cumulative sum."""
    from repro.optim.compression import init_error_feedback, roundtrip
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.randn(8, 8).astype(np.float32) * scale)}
    ef = init_error_feedback(g)
    total = jnp.zeros_like(g["w"])
    steps = 30
    for _ in range(steps):
        out, ef = roundtrip(g, ef)
        total = total + out["w"]
    rel = float(jnp.abs(total - steps * g["w"]).max()) \
        / (float(jnp.abs(g["w"]).max()) * steps + 1e-9)
    assert rel < 0.02


@given(
    u=hnp.arrays(np.float32, (16,), elements=st.floats(0.0625, 0.9375,
                                                       width=32)),
)
@settings(**SET)
def test_power_model_monotone_on_monotone_data(u):
    """Fit on a monotone curve -> predictions ordered like inputs."""
    from repro.core import power
    cpu = jnp.linspace(0.01, 1.0, 300)
    pw = 50.0 + 400.0 * cpu ** 1.1
    coef, breaks = power.fit_pd_model(cpu, pw)
    us = np.sort(np.unique(u))
    if len(us) < 2:
        return
    pred = np.asarray(power.pd_power(coef, breaks, jnp.asarray(us)))
    assert np.all(np.diff(pred) > -1.0)     # monotone up to fit noise
