"""CVaR ensemble optimizer (repro.core.risk): objective properties, the
degenerate-ensemble bitwise contracts, and the kernel dispatch parity.

Conventions under test (see risk.py): ``risk_beta`` is the averaged
worst-tail FRACTION — beta=1 is the risk-neutral mean (today's
point-forecast path), smaller beta is more risk-averse. Bitwise notes:

* K=1 ensembles are statically collapsed inside ``solve_vcc`` to the
  point-forecast problem, so the degenerate risk path runs the EXACT
  legacy graph (hard bitwise contract, kernel path included).
* K identical members collapse bitwise at the STEP level (the member
  reduction is anchored on member 0, so every deviation is exactly 0.0).
  The full solve compiles ensemble and plain epochs as different XLA
  programs, which may legally differ in fusion/FMA choices (the same
  caveat sim.engine documents for standalone-vs-scan compilation), so the
  solve-level check asserts a few-ulp ceiling rather than equality.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import risk, vcc
from repro.kernels.vcc_pgd import ref as kref

f32 = jnp.float32


# the one synthetic problem recipe shared with the parity tests and the
# solve-cost benchmark probe
_vcc_problem = vcc.synthetic_problem


def _identical_ensemble(p, K):
    eta_ens = jnp.broadcast_to(p.eta[None], (K,) + p.eta.shape)
    uif_ens = jnp.broadcast_to(p.u_if[None], (K,) + p.u_if.shape)
    return eta_ens, uif_ens


def _perturbed_ensemble(p, K, seed=0, vol=0.5):
    """Correlated whole-day intensity perturbations (member 0 = point
    forecast, like risk.sample_eta_ensemble's resampled-day structure)."""
    prof = 1.0 + vol * jax.random.normal(jax.random.PRNGKey(seed),
                                         (K, 1, 24))
    eta_ens = jnp.clip(
        jnp.broadcast_to(p.eta[None], (K,) + p.eta.shape)
        * prof.at[0].set(1.0), 1e-4, None)
    _, uif_ens = _identical_ensemble(p, K)
    return eta_ens, uif_ens


# ------------------------------------------------------- CVaR properties

def test_cvar_beta_one_is_mean():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 5))
    np.testing.assert_allclose(np.asarray(risk.cvar(x, 1.0, axis=0)),
                               np.asarray(x.mean(axis=0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(risk.soft_cvar(x, 1.0, axis=0)),
                               np.asarray(x.mean(axis=0)), rtol=1e-5,
                               atol=1e-6)


def test_cvar_beta_to_zero_is_max():
    x = jax.random.normal(jax.random.PRNGKey(1), (32,))
    np.testing.assert_allclose(np.asarray(risk.cvar(x, 1e-9)),
                               np.asarray(x.max()), rtol=1e-6)


def test_cvar_monotone_in_beta():
    """Smaller beta = averaging fewer, worse outcomes = larger value:
    CVaR is monotone non-increasing in beta (equivalently non-decreasing
    in the risk aversion 1-beta). Holds for the hard and soft forms."""
    x = jax.random.normal(jax.random.PRNGKey(2), (64,)) * 3.0
    betas = [0.05, 0.2, 0.5, 0.9, 1.0]
    hard = [float(risk.cvar(x, b)) for b in betas]
    soft = [float(risk.soft_cvar(x, b)) for b in betas]
    assert all(a >= b - 1e-5 for a, b in zip(hard, hard[1:])), hard
    assert all(a >= b - 1e-5 for a, b in zip(soft, soft[1:])), soft


def test_soft_cvar_between_mean_and_max():
    x = jax.random.normal(jax.random.PRNGKey(3), (24,)) * 2.0
    for b in (0.1, 0.5, 0.9):
        v = float(risk.soft_cvar(x, b))
        assert float(x.mean()) - 1e-5 <= v <= float(x.max()) + 1e-5


def test_cvar_sharpness_endpoints():
    assert float(kref.cvar_sharpness(1.0)) == 0.0
    assert float(kref.cvar_sharpness(0.5)) > 0.0
    # traced beta works (the day cycle carries beta as a data leaf)
    assert float(jax.jit(kref.cvar_sharpness)(jnp.asarray(0.9))) > 0.0


# ------------------------------------------- degenerate-ensemble parity

def test_k1_ensemble_bitwise_identical_to_plain_solve():
    """Acceptance contract: the K=1 / beta->1 ensemble path IS today's
    solve_vcc, bitwise — jnp oracle and interpret-mode kernel both."""
    p = _vcc_problem()
    eta_ens, uif_ens = _identical_ensemble(p, 1)
    for kw in (dict(use_pallas=False), dict(interpret=True)):
        plain = vcc.solve_vcc(p, inner_iters=40, outer_iters=4, **kw)
        # beta->1 (risk-neutral) and a risk-averse beta: K=1 must collapse
        # identically for ANY beta
        for beta in (1.0, 0.5):
            pe = risk.attach_ensemble(p, eta_ens, uif_ens, beta)
            ens = vcc.solve_vcc(pe, inner_iters=40, outer_iters=4, **kw)
            for name in ("delta", "y", "vcc", "shaped", "mu", "objective"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ens, name)),
                    np.asarray(getattr(plain, name)),
                    err_msg=f"{name} (beta={beta}, {kw})")


def test_identical_members_step_bitwise():
    """The anchored member reduction: K identical members produce the
    EXACT single-member PGD step (every deviation is exactly 0.0)."""
    p = _vcc_problem(n=6)
    K = 8
    tau24 = p.tau[:, None] / 24.0
    price = jnp.full((6, 1), 0.05, f32)
    lo = jnp.full((6, 24), -0.8, f32)
    ub = jnp.full((6, 24), 2.0, f32)
    lr = jnp.full((6, 1), 0.01, f32)
    d = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (6, 24))
    eta_e = jnp.broadcast_to(p.eta[None], (K, 6, 24))
    pow_e = jnp.broadcast_to(p.pow_nom[None], (K, 6, 24))
    plain = kref.pgd_step_arrays(d, p.eta, p.pi, p.pow_nom, tau24, price,
                                 lo, ub, lr, 10.0, 0.1)
    for beta in (1.0, 0.5, 0.1):
        ens = kref.pgd_step_ens_arrays(d, eta_e, p.pi, pow_e, tau24, price,
                                       lo, ub, lr, 10.0, 0.1,
                                       kref.cvar_sharpness(beta))
        np.testing.assert_array_equal(np.asarray(ens), np.asarray(plain),
                                      err_msg=f"beta={beta}")


def test_identical_members_solve_collapses_to_plain():
    """K=8 identical members == K=1 == plain solve. Bitwise at the step
    level (above); at the solve level ensemble and plain epochs are
    different XLA programs whose fusion/FMA choices may legally differ,
    so assert a few-ulp ceiling on the compounded drift."""
    p = _vcc_problem()
    eta_ens, uif_ens = _identical_ensemble(p, 8)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.5)
    plain = vcc.solve_vcc(p, inner_iters=40, outer_iters=4,
                          use_pallas=False)
    ens = vcc.solve_vcc(pe, inner_iters=40, outer_iters=4,
                        use_pallas=False)
    np.testing.assert_allclose(np.asarray(ens.delta),
                               np.asarray(plain.delta),
                               rtol=0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ens.vcc), np.asarray(plain.vcc),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ens.shaped),
                                  np.asarray(plain.shaped))


# ------------------------------------------------------- kernel dispatch

def test_ens_interpret_kernel_matches_ref():
    """The ensemble Pallas kernel (interpret mode on CPU) must match the
    jnp ensemble oracle inside solve_vcc — same member-reduction math,
    two dispatch targets (mirrors the plain-kernel parity test)."""
    p = _vcc_problem()
    eta_ens, uif_ens = _perturbed_ensemble(p, 8)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.5)
    ref = vcc.solve_vcc(pe, inner_iters=40, outer_iters=4,
                        use_pallas=False)
    ker = vcc.solve_vcc(pe, inner_iters=40, outer_iters=4, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.delta), np.asarray(ref.delta),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ker.vcc), np.asarray(ref.vcc),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ker.shaped),
                                  np.asarray(ref.shaped))


def test_ens_epoch_kernel_tiling_covers_remainder():
    """Cluster counts that do not divide the ensemble tile must pad
    cleanly (dead rows projected to zero, then sliced off)."""
    p = _vcc_problem(n=7)
    eta_ens, uif_ens = _perturbed_ensemble(p, 3)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.7)
    ref = vcc.solve_vcc(pe, inner_iters=10, outer_iters=2,
                        use_pallas=False)
    ker = vcc.solve_vcc(pe, inner_iters=10, outer_iters=2, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.delta), np.asarray(ref.delta),
                               rtol=1e-4, atol=1e-5)


def test_ens_kernel_k32_sweep_size():
    """The largest sweep size (K=32, sim.RISK_MEMBERS) goes through the
    ensemble kernel's (K, tile, 24) member slabs."""
    from repro.sim import RISK_MEMBERS
    K = max(RISK_MEMBERS)
    p = _vcc_problem(n=4)
    eta_ens, uif_ens = _perturbed_ensemble(p, K)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.9)
    ref = vcc.solve_vcc(pe, inner_iters=5, outer_iters=1,
                        use_pallas=False)
    ker = vcc.solve_vcc(pe, inner_iters=5, outer_iters=1, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.delta), np.asarray(ref.delta),
                               rtol=1e-4, atol=1e-5)


# ------------------------------------------------- risk-averse behavior

def test_risk_averse_solve_improves_soft_cvar():
    """Descending the soft-CVaR tilt must (weakly) beat the risk-neutral
    delta ON that objective, for every sweep beta."""
    p = _vcc_problem()
    eta_ens, uif_ens = _perturbed_ensemble(p, 8)
    neutral = vcc.solve_vcc(p, use_pallas=False)
    for beta in (0.5, 0.9, 0.99):
        pr = risk.attach_ensemble(p, eta_ens, uif_ens, beta)
        sr = vcc.solve_vcc(pr, use_pallas=False)
        got = float(risk.soft_cvar_objective(pr, sr.delta, sr.mu))
        ref = float(risk.soft_cvar_objective(pr, neutral.delta, neutral.mu))
        assert got <= ref + 1e-3 * abs(ref), \
            f"beta={beta}: soft CVaR {got} > neutral {ref}"


def test_member_objectives_member0_is_nominal():
    """Member 0 is the point forecast: its cost must equal the nominal
    eq. 4 objective (same hard-peak form) to float tolerance."""
    p = _vcc_problem()
    eta_ens, uif_ens = _identical_ensemble(p, 4)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.9)
    sol = vcc.solve_vcc(p, inner_iters=10, outer_iters=2, use_pallas=False)
    objs = risk.member_objectives(pe, sol.delta, sol.mu)
    assert objs.shape == (4,)
    np.testing.assert_allclose(
        float(objs[0]),
        float(vcc.objective(p, sol.delta, sol.mu)), rtol=1e-5)


def test_ensemble_solve_jit_and_vmap():
    """Ensemble problems ride jit and vmap (batched risk sweeps)."""
    p = _vcc_problem(n=6)
    eta_ens, uif_ens = _perturbed_ensemble(p, 4)
    pe = risk.attach_ensemble(p, eta_ens, uif_ens, 0.5)
    eager = vcc.solve_vcc(pe, inner_iters=10, outer_iters=2,
                          use_pallas=False)
    jitted = jax.jit(lambda q: vcc.solve_vcc(q, inner_iters=10,
                                             outer_iters=2,
                                             use_pallas=False))(pe)
    np.testing.assert_allclose(np.asarray(jitted.delta),
                               np.asarray(eager.delta), rtol=1e-5,
                               atol=1e-6)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), pe, pe)
    solb = vcc.solve_vcc_batched(stacked, inner_iters=10, outer_iters=2,
                                 use_pallas=False)
    assert solb.delta.shape == (2, 6, 24)


def test_sampled_ensembles_member0_is_point_forecast():
    """risk.sample_* pin member 0 to the point forecast bitwise, and all
    members stay in sane ranges."""
    key = jax.random.PRNGKey(9)
    n, D = 5, 10
    uif_pred = jnp.abs(1.0 + 0.2 * jax.random.normal(key, (n, 24)))
    hist_act = jnp.abs(1.0 + 0.3 * jax.random.normal(key, (n, D, 24)))
    hist_pred = jnp.abs(1.0 + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 1), (n, D, 24)))
    ens = risk.sample_uif_ensemble(key, uif_pred, hist_pred, hist_act, 6)
    assert ens.shape == (6, n, 24)
    np.testing.assert_array_equal(np.asarray(ens[0]), np.asarray(uif_pred))
    assert np.all(np.asarray(ens) >= 0.0)

    fc_z = jnp.abs(0.4 + 0.1 * jax.random.normal(key, (3, 24)))
    chist = jnp.abs(0.4 + 0.1 * jax.random.normal(key, (3, D, 24)))
    zmap = jnp.asarray([0, 1, 2, 0, 1], jnp.int32)
    eta = risk.sample_eta_ensemble(key, fc_z, chist, zmap, 6)
    assert eta.shape == (6, n, 24)
    np.testing.assert_array_equal(np.asarray(eta[0]),
                                  np.asarray(fc_z[zmap]))
    assert np.all(np.asarray(eta) > 0.0)
