"""Sim subsystem: scenario purity, ledger accounting, batched parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import (Scenario, SimConfig, build_batch, build_params,
                       default_library, init_ledger, ledger_update,
                       make_init, make_rollout, rollout_batch,
                       rollout_batch_sharded, rollout_sequential, summarize)
from repro.sim.ledger import DayMetrics
from repro.sim.scenarios import ClusterOutage, DemandSurge, RenewableDrought

CFG = SimConfig(n_clusters=2, n_campuses=2, n_zones=2, pds_per_cluster=2,
                hist_days=14)
DAYS = 2


def test_scenario_composition_deterministic():
    """build_params is pure: same (cfg, scenario, seed, days) -> identical
    arrays, including perturbations with internal randomness."""
    sc = Scenario("combo", "drought+outage+surge",
                  (RenewableDrought(start=1, depth=0.5),
                   ClusterOutage(start=0, length=1, frac=0.5),
                   DemandSurge(start=1, scale=1.5)))
    a = build_params(CFG, sc, seed=3, days=4)
    b = build_params(CFG, sc, seed=3, days=4)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # a different seed must change the fleet (and the outage draw)
    c = build_params(CFG, sc, seed=4, days=4)
    assert not np.allclose(np.asarray(a.truth["capacity"]),
                           np.asarray(c.truth["capacity"]))


def test_scenario_schedules_shapes_and_effects():
    sc = Scenario("drought", "", (RenewableDrought(start=1, depth=0.7),))
    p = build_params(CFG, sc, seed=0, days=3)
    g = np.asarray(p.green_scale)
    assert g.shape == (3, CFG.n_zones)
    np.testing.assert_allclose(g[0], 1.0)
    np.testing.assert_allclose(g[1:], 0.3, rtol=1e-6)


def test_ledger_matches_hand_computed_2cluster_2day():
    """Feed a hand-written 2-cluster / 2-day rollout through the ledger and
    check every cumulative total against numpy arithmetic."""
    n = 2
    led = init_ledger(n)
    days = []
    for d in range(2):
        power = np.array([[1.0 + d, 2.0], [3.0, 4.0 + d]])    # (n, hours=2)
        intensity = np.array([[0.5, 1.0], [1.0, 0.25]])
        carbon = power * intensity
        m = DayMetrics(
            carbon_kg=jnp.asarray(carbon.sum(1), jnp.float32),
            kwh=jnp.asarray(power.sum(1), jnp.float32),
            peak_kw=jnp.asarray(power.max(1), jnp.float32),
            served=jnp.asarray([1.0, 2.0 + d], jnp.float32),
            arrived=jnp.asarray([2.0, 2.0 + d], jnp.float32),
            unmet=jnp.asarray([0.5, 0.0], jnp.float32),
            queue_end=jnp.asarray([1.0, 0.0 + d], jnp.float32),
            cf_carbon_kg=jnp.asarray(carbon.sum(1) * 1.25, jnp.float32),
            cf_kwh=jnp.asarray(power.sum(1) * 1.1, jnp.float32),
            cf_peak_kw=jnp.asarray(power.max(1) * 0.9, jnp.float32),
            cf_served=jnp.asarray([2.0, 2.0 + d], jnp.float32),
            cf_queue_end=jnp.asarray([0.0, 0.0], jnp.float32),
        )
        days.append(m)
        led = ledger_update(led, m)
    assert float(led.days) == 2.0
    np.testing.assert_allclose(
        np.asarray(led.carbon_kg),
        sum(np.asarray(m.carbon_kg) for m in days), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(led.kwh), sum(np.asarray(m.kwh) for m in days),
        rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(led.peak_kw),
        np.maximum(*[np.asarray(m.peak_kw) for m in days]), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(led.delayed_cpu_h),
        sum(np.asarray(m.queue_end) for m in days), rtol=1e-6)
    # summary math
    s = summarize(led)
    carbon = float(led.carbon_kg.sum())
    cf_carbon = float(led.cf_carbon_kg.sum())
    assert abs(float(s["carbon_saved_pct"])
               - 100.0 * (cf_carbon - carbon) / cf_carbon) < 1e-4
    # cf = shaped * 1.25 => exactly 20% saved
    assert abs(float(s["carbon_saved_pct"]) - 20.0) < 1e-3
    unmet = sum(float(np.asarray(m.unmet).sum()) for m in days)
    arrived = sum(float(np.asarray(m.arrived).sum()) for m in days)
    assert abs(float(s["flex_within_24h_pct"])
               - 100.0 * (1 - unmet / arrived)) < 1e-4


def test_flex_completion_capped_with_initial_backlog():
    """Regression: when a burned-in backlog drains during the rollout,
    served work exceeds in-horizon arrivals. Completion must be reported
    as served-of-(arrived + initial backlog) and never exceed 100%."""
    n = 2
    led = init_ledger(n)
    z = jnp.zeros((n,), jnp.float32)
    m = DayMetrics(
        carbon_kg=jnp.ones((n,)), kwh=jnp.ones((n,)),
        peak_kw=jnp.ones((n,)),
        served=jnp.asarray([15.0, 12.0]),    # > arrived: backlog drained
        arrived=jnp.asarray([10.0, 10.0]),
        unmet=z, queue_end=z,
        cf_carbon_kg=jnp.ones((n,)), cf_kwh=jnp.ones((n,)),
        cf_peak_kw=jnp.ones((n,)),
        cf_served=jnp.asarray([15.0, 12.0]), cf_queue_end=z)
    led = ledger_update(led, m)
    # without the backlog term the ratio is 27/20 -> clipped to 100
    assert float(summarize(led)["flex_completion_pct"]) == 100.0
    # with the true initial backlog (7 CPU-h) it is exactly 100
    s = summarize(led, initial_backlog=7.0)
    np.testing.assert_allclose(float(s["flex_completion_pct"]), 100.0,
                               rtol=1e-6)
    # an over-estimated backlog yields a true fraction below 100
    s = summarize(led, initial_backlog=13.0)
    np.testing.assert_allclose(float(s["flex_completion_pct"]),
                               100.0 * 27.0 / 33.0, rtol=1e-6)


def test_vmap_batch_matches_sequential_runs():
    """A vmap'd batch of 4 scenarios must reproduce 4 separate
    (non-batched, day-sequential) rollouts BITWISE — the engine's parity
    contract. The Python-loop driver of the same jitted day step agrees to
    float tolerance (standalone-vs-scan-body compilation differs in
    FMA/fusion choices, which bitwise equality cannot survive)."""
    scens = default_library(DAYS)[:4]
    batch = build_batch(CFG, scens, [0], DAYS)
    run = rollout_batch(CFG, DAYS)
    stB, ledB, trajB = run(batch)
    init = jax.jit(make_init(CFG))
    roll = jax.jit(make_rollout(CFG, DAYS))
    for i, sc in enumerate(scens):
        p = build_params(CFG, sc, 0, DAYS)
        st, led, traj = roll(p, init(p))
        for a, b in zip(jax.tree.leaves((stB, ledB, trajB)),
                        jax.tree.leaves((st, led, traj))):
            np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b),
                                          err_msg=sc.name)
    # single-element batch must also match (batch-size invariance)
    b1 = build_batch(CFG, [scens[0]], [0], DAYS)
    _, led1, _ = run(b1)
    for a, b in zip(jax.tree.leaves(led1), jax.tree.leaves(ledB)):
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    # python-loop sequential driver ~= scan rollout
    p = build_params(CFG, scens[0], 0, DAYS)
    st0 = init(p)
    _, led_scan, _ = roll(p, st0)
    _, led_seq = rollout_sequential(CFG, DAYS, p, st0)
    for a, b in zip(jax.tree.leaves(led_scan), jax.tree.leaves(led_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_sharded_batch_matches_unsharded():
    """rollout_batch_sharded (shard_map over the 1-D device mesh) must
    reproduce rollout_batch BITWISE: rollouts are embarrassingly parallel
    and the numerics are batch-invariant, so device placement must not
    change a single bit. Also: a batch that does not divide across the
    mesh is rejected loudly."""
    scens = default_library(DAYS)[:3]
    # size the batch to divide whatever mesh the host offers
    batch = build_batch(CFG, scens, list(range(len(jax.devices()))), DAYS)
    _, led, traj = rollout_batch(CFG, DAYS)(batch)
    run_sharded = rollout_batch_sharded(CFG, DAYS)
    _, led_s, traj_s = run_sharded(batch)
    for a, b in zip(jax.tree.leaves((led, traj)),
                    jax.tree.leaves((led_s, traj_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n_dev = len(jax.devices())
    if n_dev > 1:                              # pragma: no cover
        bad = build_batch(CFG, scens[:1], list(range(n_dev + 1)), DAYS)
        with pytest.raises(ValueError, match="divide"):
            run_sharded(bad)


def test_counterfactual_serves_no_less():
    """The unshaped counterfactual admits flexible work at least as fast
    as the shaped run (VCC only ever restricts admission)."""
    p = build_params(CFG, default_library(DAYS)[0], 0, DAYS)
    init = jax.jit(make_init(CFG))
    roll = jax.jit(make_rollout(CFG, DAYS))
    _, led, _ = roll(p, init(p))
    assert float(led.cf_delayed_cpu_h.sum()) <= \
        float(led.delayed_cpu_h.sum()) + 1e-3
