"""Spatial shifting invariants: conservation, mobility bounds, carbon
monotonicity (flexible work moves toward cleaner clusters)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spatial import spatial_shift, spatial_shift_batched
from repro.core.vcc import VCCProblem


def _problem(n=8, seed=0, eta_spread=3.0):
    rng = np.random.RandomState(seed)
    H = 24
    capacity = jnp.asarray(8.0 + 4.0 * rng.rand(n), jnp.float32)
    u_if = jnp.asarray(2.0 + rng.rand(n, H), jnp.float32)
    tau = jnp.asarray(10.0 + 5.0 * rng.rand(n), jnp.float32)
    eta = jnp.asarray(0.2 + eta_spread * rng.rand(n, 1)
                      * np.ones((1, H)), jnp.float32)
    return VCCProblem(
        eta=eta, u_if=u_if, u_if_q=u_if * 1.1, tau=tau,
        pow_nom=jnp.ones((n, H)) * 500.0, pi=jnp.ones((n, H)) * 300.0,
        u_pow_cap=capacity * 0.95, capacity=capacity,
        ratio=jnp.ones((n, H)) * 1.3,
        campus=jnp.zeros((n,), jnp.int32),
        campus_limit=jnp.asarray([1e9], jnp.float32))


def test_conservation():
    p = _problem()
    tau2, _ = spatial_shift(p, mobility=0.3)
    assert float(jnp.abs(tau2.sum() - p.tau.sum())) < 1e-3 * float(
        p.tau.sum())


def test_mobility_bounds():
    p = _problem()
    mob = 0.25
    tau2, _ = spatial_shift(p, mobility=mob)
    export = np.asarray(p.tau - tau2)          # positive = work moved away
    # no cluster exports more than mobility * its own flexible budget
    assert (export <= mob * np.asarray(p.tau) + 1e-4).all()
    # zero mobility = identity
    tau0, _ = spatial_shift(p, mobility=0.0)
    np.testing.assert_allclose(np.asarray(tau0), np.asarray(p.tau),
                               rtol=1e-6)


def test_carbon_monotonicity():
    """Work flows from carbon-expensive to carbon-cheap clusters, and the
    shifted allocation's expected carbon never exceeds the original."""
    p = _problem(eta_spread=4.0)
    tau2, price = spatial_shift(p, mobility=0.4)
    price = np.asarray(price)
    moved = np.asarray(tau2 - p.tau)           # positive = net import
    # expected-carbon objective must not increase
    before = float((np.asarray(p.tau) * price).sum())
    after = float((np.asarray(tau2) * price).sum())
    assert after <= before + 1e-3 * abs(before)
    # importers are on average cheaper than exporters
    if (moved > 1e-4).any() and (moved < -1e-4).any():
        assert price[moved > 1e-4].mean() <= price[moved < -1e-4].mean()


def test_batched_matches_single():
    probs = [_problem(seed=s) for s in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    tb, pb = spatial_shift_batched(stacked, mobility=0.3)
    for i, p in enumerate(probs):
        ts, ps = spatial_shift(p, mobility=0.3)
        np.testing.assert_allclose(np.asarray(tb[i]), np.asarray(ts),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(pb[i]), np.asarray(ps),
                                   rtol=1e-5)


def test_solve_vcc_batched_matches_single():
    from repro.core.vcc import solve_vcc, solve_vcc_batched
    probs = [_problem(seed=s) for s in range(2)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    solb = solve_vcc_batched(stacked, inner_iters=20, outer_iters=4)
    for i, p in enumerate(probs):
        sol = solve_vcc(p, inner_iters=20, outer_iters=4)
        np.testing.assert_allclose(np.asarray(solb.vcc[i]),
                                   np.asarray(sol.vcc), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(solb.shaped[i]),
                                      np.asarray(sol.shaped))
