"""The staged core is the ONE day cycle: the legacy fleet adapters and the
sim engine must produce identical states/VCCs from the same inputs, and
solve_vcc's kernel dispatch path must match its jnp oracle path on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import vcc
from repro.sim import (Scenario, SimConfig, build_params, make_day_step,
                       make_init)
from repro.sim.engine import _day_xs

N, M, Z, PDS, HIST = 4, 2, 2, 2, 14
SEED = 0
LAMBDA_E, LAMBDA_P, GAMMA = 0.5, 0.05, 0.05

SIM_CFG = SimConfig(n_clusters=N, n_campuses=M, n_zones=Z,
                    pds_per_cluster=PDS, hist_days=HIST)
FLEET_CFG = F.FleetConfig(n_clusters=N, n_campuses=M, n_zones=Z,
                          pds_per_cluster=PDS, lambda_e=LAMBDA_E,
                          lambda_p=LAMBDA_P, gamma=GAMMA, seed=SEED,
                          hist_days=HIST)


@pytest.fixture(scope="module")
def engine_side():
    sc = Scenario("parity_probe", lambda_e=LAMBDA_E, lambda_p=LAMBDA_P,
                  gamma=GAMMA)
    params = build_params(SIM_CFG, sc, seed=SEED, days=3)
    state = jax.jit(make_init(SIM_CFG))(params)
    return params, state


@pytest.fixture(scope="module")
def fleet_side():
    return F.init_fleet(FLEET_CFG)


def test_legacy_burnin_matches_engine_init(engine_side, fleet_side):
    """init_fleet (FleetState wrapper) and the engine's make_init burn in
    the SAME state bitwise — one lax.scan burn-in, two adapters."""
    _, s = engine_side
    st = fleet_side
    for name, a, b in (
            ("hist_uif", st.hist_uif, s.hist_uif),
            ("hist_usage", st.hist_usage, s.hist_usage),
            ("hist_res", st.hist_res, s.hist_res),
            ("hist_flex_daily", st.hist_flex_daily, s.hist_flex_daily),
            ("hist_res_daily", st.hist_res_daily, s.hist_res_daily),
            ("hist_tr_pred", st.hist_tr_pred, s.hist_tr_pred),
            ("hist_uif_pred", st.hist_uif_pred, s.hist_uif_pred),
            ("carbon_hist", st.carbon_hist, s.carbon_hist),
            ("campus_limit", st.campus_limit, s.campus_limit),
            ("queue", st.queue, s.queue),
            ("cf_queue", st.cf_queue, s.cf_queue)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    assert int(st.day) == int(s.day) == HIST


def test_day_cycle_matches_engine_day_step(engine_side, fleet_side):
    """Three legacy day_cycle days == three engine day_step days, bitwise:
    identical VCC curves, admission results, rolled histories, SLO state."""
    params, s = engine_side
    st = fleet_side
    step = jax.jit(make_day_step(SIM_CFG))
    for d in range(3):
        s, out = step(params, s, _day_xs(params, d))
        rec = {}
        st = F.day_cycle(st, rec)
        np.testing.assert_array_equal(np.asarray(rec["vcc"]),
                                      np.asarray(out.vcc_curve),
                                      err_msg=f"vcc day {d}")
        for name, a, b in (
                ("delta", rec["sol"].delta, out.sol.delta),
                ("shaped", rec["sol"].shaped, out.sol.shaped),
                ("carbon", rec["result"].carbon, out.res.carbon),
                ("served", rec["result"].served, out.res.served),
                ("cf_carbon", rec["cf_result"].carbon, out.cf.carbon),
                ("intensity", rec["intensity"], out.eta_act)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} day {d}")
        # carried state stays in lockstep
        for name, a, b in (
                ("queue", st.queue, s.queue),
                ("cf_queue", st.cf_queue, s.cf_queue),
                ("hist_usage", st.hist_usage, s.hist_usage),
                ("shaping_allowed", st.shaping_allowed,
                 s.shaping_allowed),
                ("pause_left", st.slo_state["pause_left"], s.pause_left)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} day {d}")
        assert int(st.day) == int(s.day)


# the shared synthetic recipe (identical arrays to the old inline copy)
_vcc_problem = vcc.synthetic_problem


def test_solve_vcc_interpret_kernel_matches_ref():
    """The vcc_pgd kernel path INSIDE solve_vcc (Pallas interpreter on
    CPU) must match the jnp oracle path: same inner-loop math, two
    dispatch targets."""
    p = _vcc_problem()
    ref = vcc.solve_vcc(p, inner_iters=40, outer_iters=4, use_pallas=False)
    ker = vcc.solve_vcc(p, inner_iters=40, outer_iters=4, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.delta), np.asarray(ref.delta),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ker.vcc), np.asarray(ref.vcc),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ker.shaped),
                                  np.asarray(ref.shaped))
    np.testing.assert_allclose(float(ker.objective), float(ref.objective),
                               rtol=1e-5)


def test_solve_vcc_traced_scalars_under_jit_and_vmap():
    """The dispatcher accepts traced temp/lambda_e: solve_vcc must jit and
    vmap cleanly through kernels.vcc_pgd.ops (the old wrapper called
    float() on them and could not)."""
    p = _vcc_problem(n=6)
    sol_eager = vcc.solve_vcc(p, inner_iters=10, outer_iters=2)
    sol_jit = jax.jit(lambda q: vcc.solve_vcc(q, inner_iters=10,
                                              outer_iters=2))(p)
    np.testing.assert_allclose(np.asarray(sol_jit.delta),
                               np.asarray(sol_eager.delta),
                               rtol=1e-5, atol=1e-6)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           _vcc_problem(n=6, seed=1),
                           _vcc_problem(n=6, seed=2))
    solb = vcc.solve_vcc_batched(stacked, inner_iters=10, outer_iters=2)
    assert solb.delta.shape == (2, 6, 24)
