"""Hypothesis property tests for core/stats.py (PR 5 satellite).

The streaming prediction layer's contract: every incremental estimator
equals its batch recomputation —

  * the EWMA carry applies ``forecast.ewma``'s recursion EXACTLY, so
    stepping ``ewma_update`` over a series is bitwise the batch scan;
  * exponentially-weighted regression moments reproduce a direct
    weighted least-squares fit within float tolerance;
  * ring buffers are exact windows: their quantiles equal the quantile
    of the trailing raw values bitwise.

Skips as a unit when the `hypothesis` capability is absent (the CI
workflow installs it and runs these under the fixed-seed `ci` profile).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="capability check: the `hypothesis` package is not importable "
           "here; CI installs it (see .github/workflows/ci.yml) and runs "
           "these property tests under the fixed-seed 'ci' profile")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import forecast, stats  # noqa: E402

SET = dict(max_examples=25, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(
    x=hnp.arrays(np.float32, (20,),
                 elements=st.floats(0.0, 100.0, width=32)),
    hl=st.floats(0.1, 16.0),
)
@settings(**SET)
def test_ewma_incremental_matches_batch_scan_bitwise(x, hl):
    """Carrying ``ewma_update`` across the series is the SAME recursion
    ``forecast.ewma`` scans — level bitwise-equal at every length. The
    incremental step runs COMPILED (``jax.jit``), as it always does in
    the streaming day step: XLA contracts the step's mul+add identically
    in the straight-line and scan-body forms (fully-eager dispatch may
    differ in the last ulp — the repo-wide eager-vs-compiled caveat)."""
    upd = jax.jit(forecast.ewma_update)
    alpha = forecast.ewma_alpha(hl)
    level = jnp.asarray(x[0])
    for i, xi in enumerate(x[1:], start=2):
        level = upd(level, jnp.asarray(xi), alpha)
        batch = forecast.ewma(jnp.asarray(x[:i]), hl)
        np.testing.assert_array_equal(np.asarray(level), np.asarray(batch))


@given(
    x=hnp.arrays(np.float64, (6, 8),
                 elements=st.floats(-5.0, 5.0, width=64)),
    noise=hnp.arrays(np.float64, (6, 8),
                     elements=st.floats(-0.5, 0.5, width=64)),
    a=st.floats(-2.0, 2.0),
    b=st.floats(-2.0, 2.0),
    hl=st.floats(1.0, 20.0),
)
@settings(**SET)
def test_ew_moments_match_direct_weighted_least_squares(x, noise, a, b, hl):
    """T daily batches absorbed through ``ew_update`` fit y ~ a + b x
    identically (within float tolerance) to a direct weighted LSQ with
    per-day weights rho^(T-1-t)."""
    T, k = x.shape
    y = a + b * x + noise
    rho = float(stats.decay_from_half_life(hl))
    m = stats.ew_init(jnp.asarray(x[:1], jnp.float32).reshape(1, -1),
                      jnp.asarray(y[:1], jnp.float32).reshape(1, -1))
    for t in range(1, T):
        m = stats.ew_update(m, jnp.asarray(x[t:t + 1], jnp.float32),
                            jnp.asarray(y[t:t + 1], jnp.float32), rho)
    a_s, b_s = stats.ew_linfit(m)
    # direct weighted normal equations in float64
    w = np.repeat(rho ** np.arange(T - 1, -1, -1.0), k)
    xf, yf = x.reshape(-1), y.reshape(-1)
    sw, sx, sy = w.sum(), (w * xf).sum(), (w * yf).sum()
    sxx, sxy = (w * xf * xf).sum(), (w * xf * yf).sum()
    den = sxx - sx * sx / sw
    if den < 1e-3 * sw:        # degenerate x spread: fit ill-conditioned
        return
    b_d = (sxy - sx * sy / sw) / den
    a_d = sy / sw - b_d * sx / sw
    np.testing.assert_allclose(float(b_s[0]), b_d, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(a_s[0]), a_d, rtol=2e-3, atol=2e-2)


@given(
    init=hnp.arrays(np.float32, (3, 5),
                    elements=st.floats(-10.0, 10.0, width=32)),
    pushes=hnp.arrays(np.float32, (9, 3),
                      elements=st.floats(-10.0, 10.0, width=32)),
    q=st.floats(0.0, 1.0),
)
@settings(**SET)
def test_ring_buffer_quantiles_exact(init, pushes, q):
    """After any number of pushes the ring holds EXACTLY the trailing W
    values; its quantile equals the quantile of that window bitwise."""
    ring = jnp.asarray(init)
    hist = [init[:, i] for i in range(init.shape[1])]
    for row in pushes:
        ring = stats.ring_push(ring, jnp.asarray(row))
        hist.append(row)
        window = jnp.asarray(np.stack(hist[-init.shape[1]:], axis=1))
        np.testing.assert_array_equal(np.asarray(ring), np.asarray(window))
        np.testing.assert_array_equal(
            np.asarray(stats.ring_quantile(ring, q)),
            np.asarray(jnp.quantile(window, q, axis=1)))


@given(
    dev=hnp.arrays(np.float32, (9,),
                   elements=st.floats(-3.0, 3.0, width=32)),
)
@settings(**SET)
def test_dev_moments_init_matches_deviation_coef(dev):
    """``dev_init`` + ``dev_coef`` on a deviation series reproduce
    ``forecast.deviation_coef``'s through-origin estimate bitwise (same
    pairing, same sum order, same clips)."""
    d = jnp.asarray(dev)[None]
    got = stats.dev_coef(stats.dev_init(d))
    want = forecast.deviation_coef(d[0], jnp.zeros_like(d[0]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want))
