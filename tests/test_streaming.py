"""Streaming prediction layer (PR 5 tentpole): equivalence + plumbing.

Contracts pinned here:

  * **Handoff bitwise** — ``stats.init_predictor`` warm-starts the
    streaming estimators from a burned-in rescan window with the SAME
    functions/op-orders, so the first streaming forecast equals the
    rescan forecast bitwise on the EWMA components (uif/tuf/tr, hence
    theta) and to float tolerance on the ratio-model terms (moment-form
    vs centered-form least squares). Both sides are evaluated eagerly:
    ulp-level equality across different jit compile units is out of
    contract repo-wide (same caveat as ``rollout_sequential``).
  * **Dual-run drift** — replaying 14 rescan days through the streaming
    predictor (same actuals) keeps every forecast within a documented
    tolerance: the two paths are different-memory estimators of the same
    quantities (the rescan re-partitions a sliding window daily, which
    has no O(1) update). Also CI-gated in benchmarks/sim_bench.py.
  * **State size** — the streaming carry replaces the seven (n, H[, 24])
    history windows with O(1)-in-H state (strictly smaller already at
    modest H; the H=364 gate lives in the bench).
  * **Plumbing** — streaming rollouts run under jit+vmap end to end; the
    legacy fleet adapters drive the same streaming day step; ensembles
    (n_members > 1) are rejected with a clear error.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import stages, stats
from repro.sim import (Scenario, SimConfig, build_batch, build_params,
                       make_day_step, make_init, rollout_batch)
from repro.sim.engine import _day_xs
from repro.sim.report import state_nbytes

N, M, Z, PDS, HIST = 4, 2, 2, 2, 28
CFG = SimConfig(n_clusters=N, n_campuses=M, n_zones=Z, pds_per_cluster=PDS,
                hist_days=HIST)
CFG_S = dataclasses.replace(CFG, streaming=True)
SCEN = Scenario("stream_probe", lambda_e=0.5)

# documented dual-run drift tolerance (max |streaming - rescan| / mean
# |rescan| per forecast component per day; measured ~0.19/0.15/0.04 for
# uif/tuf/tr over 14 days at this config)
DRIFT_TOL = {"uif": 0.35, "tuf": 0.35, "tr": 0.35, "alpha": 0.35,
             "uif_q": 0.45}


@pytest.fixture(scope="module")
def rescan_side():
    params = build_params(CFG, SCEN, seed=0, days=16)
    state = jax.jit(make_init(CFG))(params)
    return params, state


@pytest.fixture(scope="module")
def predictor(rescan_side):
    params, s = rescan_side
    return stats.init_predictor(
        s.hist_uif, s.hist_flex_daily, s.hist_res_daily, s.hist_usage,
        s.hist_res, s.hist_tr_pred, s.hist_uif_pred, s.day, params.gamma)


def test_handoff_forecast_bitwise_on_ewma_components(rescan_side,
                                                     predictor):
    params, s = rescan_side
    fc_r = stages.forecast_stage(
        s.hist_uif, s.hist_flex_daily, s.hist_res_daily, s.hist_usage,
        s.hist_res, s.hist_tr_pred, s.hist_uif_pred, s.day, params.gamma)
    fc_s = stats.streaming_forecast(predictor, s.day, params.gamma)
    for k in ("uif", "tuf", "tr", "theta"):
        np.testing.assert_array_equal(np.asarray(fc_r[k]),
                                      np.asarray(fc_s[k]), err_msg=k)
    for k in ("ratio_a", "ratio_b", "alpha", "uif_q"):
        np.testing.assert_allclose(np.asarray(fc_r[k]),
                                   np.asarray(fc_s[k]), rtol=1e-3,
                                   atol=1e-3, err_msg=k)


def test_streaming_init_power_fit_is_rescan_fit(rescan_side, predictor):
    """The usage ring IS the trailing 28-day window the rescan power fit
    slices: the fitted PD models agree bitwise."""
    params, s = rescan_side
    key = jax.random.fold_in(
        jax.random.fold_in(params.key, s.day), 1)
    m_r = stages.power_stage(s.hist_usage, params.lam,
                             params.truth["capacity"],
                             stages.pd_truth(params), key)
    m_s = stages.power_stage(predictor.usage_ring, params.lam,
                             params.truth["capacity"],
                             stages.pd_truth(params), key)
    np.testing.assert_array_equal(np.asarray(m_r.coef), np.asarray(m_s.coef))
    np.testing.assert_array_equal(np.asarray(m_r.breaks),
                                  np.asarray(m_s.breaks))


def test_dual_run_14_day_drift_within_tolerance(rescan_side, predictor):
    """>= 14-day dual run: step the rescan engine, replay its realized
    telemetry through the streaming predictor, compare every day's
    forecasts. Day 0 must be exact on the EWMA components; every day
    stays inside DRIFT_TOL."""
    params, s = rescan_side
    pred = predictor
    step = jax.jit(make_day_step(CFG))
    for d in range(14):
        fc_s = stats.streaming_forecast(pred, s.day, params.gamma)
        s2, out = step(params, s, _day_xs(params, d))
        for k, tol in DRIFT_TOL.items():
            a, b = np.asarray(out.fc[k]), np.asarray(fc_s[k])
            drift = np.max(np.abs(a - b)) / (np.mean(np.abs(a)) + 1e-9)
            assert drift < tol, (k, d, drift)
        if d == 0:
            np.testing.assert_allclose(np.asarray(out.fc["tr"]),
                                       np.asarray(fc_s["tr"]), rtol=1e-6)
        pred = stats.predictor_update(
            pred, fc_s, s.day, params.gamma, s2.hist_uif[:, -1],
            out.res.served, stages.hour_sum(out.res.reservations),
            out.res.usage_total, out.res.reservations)
        s = s2


def test_streaming_state_strictly_smaller(rescan_side, predictor):
    _, s = rescan_side
    pred_b = stats.predictor_nbytes(predictor)
    hist_b = stats.replaced_hist_nbytes(s)
    assert pred_b < hist_b, (pred_b, hist_b)
    # and the full carried streaming state beats the rescan state
    params = build_params(CFG_S, SCEN, seed=0, days=3)
    s_stream = jax.jit(make_init(CFG_S))(params)
    assert state_nbytes(s_stream) < state_nbytes(s)


def test_streaming_rollout_batch_runs_under_jit_vmap():
    days = 5
    scens = [SCEN, Scenario("stream_probe_hot", lambda_e=2.0)]
    batch = build_batch(CFG_S, scens, [0, 1], days)
    state, led, traj = rollout_batch(CFG_S, days)(batch)
    for leaf in jax.tree_util.tree_leaves(led):
        assert np.isfinite(np.asarray(leaf)).all()
    assert (np.asarray(led.carbon_kg).sum(axis=-1) > 0).all()
    assert (np.asarray(led.served).sum(axis=-1) > 0).all()
    assert np.asarray(traj["carbon_kg"]).shape == (4, days)
    # the carried streaming state kept its O(1) shape (incl. the 7-day
    # carbon window — the slice the forecaster actually reads)
    assert state.hist_uif.shape[2] == 0
    assert state.carbon_hist.shape[2] == stats.WEEK
    assert state.pred.usage_ring.shape[-2:] == (stats.USAGE_WINDOW, 24)


def test_fleet_streaming_day_cycle_matches_engine():
    """The legacy FleetState adapters thread the streaming carry through
    the SAME jitted staged step: two days of fleet.day_cycle equal two
    engine day steps bitwise."""
    fcfg = F.FleetConfig(n_clusters=N, n_campuses=M, n_zones=Z,
                         pds_per_cluster=PDS, lambda_e=0.5, lambda_p=0.05,
                         gamma=0.05, seed=0, hist_days=HIST, streaming=True)
    sc = Scenario("stream_parity", lambda_e=0.5, lambda_p=0.05, gamma=0.05)
    params = build_params(CFG_S, sc, seed=0, days=3)
    s = jax.jit(make_init(CFG_S))(params)
    st = F.init_fleet(fcfg)
    assert st.pred is not None
    np.testing.assert_array_equal(np.asarray(st.pred.uif_wmean),
                                  np.asarray(s.pred.uif_wmean))
    step = jax.jit(make_day_step(CFG_S))
    for d in range(2):
        s, out = step(params, s, _day_xs(params, d))
        rec = {}
        st = F.day_cycle(st, rec)
        np.testing.assert_array_equal(np.asarray(rec["vcc"]),
                                      np.asarray(out.vcc_curve),
                                      err_msg=f"vcc day {d}")
        np.testing.assert_array_equal(np.asarray(st.queue),
                                      np.asarray(s.queue),
                                      err_msg=f"queue day {d}")
        np.testing.assert_array_equal(
            np.asarray(st.pred.theta_err_ring),
            np.asarray(s.pred.theta_err_ring),
            err_msg=f"theta ring day {d}")
    assert int(st.day) == int(s.day)


def test_streaming_rejects_forecast_ensembles():
    with pytest.raises(ValueError, match="streaming"):
        stages.make_day_step(stages.StageConfig(streaming=True,
                                                n_members=4))


def test_streaming_init_requires_a_week():
    with pytest.raises(ValueError, match="hist_days"):
        stages.make_init(4, 2, 2, hist_days=6, streaming=True)
