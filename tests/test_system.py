"""End-to-end behaviour of the paper's system: the CICS day cycle shifts
flexible load toward green hours while preserving daily totals and honoring
the SLO feedback loop (paper §IV)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet as F

N_DAYS = 4


@pytest.fixture(scope="module")
def fleet_run():
    cfg = F.FleetConfig(n_clusters=8, n_campuses=2, n_zones=2, lambda_e=0.5,
                        seed=1)
    st = F.init_fleet(cfg)
    recs = []
    for _ in range(N_DAYS):
        rec = {}
        st = F.day_cycle(st, rec)
        recs.append(rec)
    return cfg, st, recs


def test_delta_anticorrelates_with_carbon(fleet_run):
    cfg, st, recs = fleet_run
    corrs = []
    for rec in recs:
        sol, eta = rec["sol"], rec["intensity"]
        for c in range(cfg.n_clusters):
            if bool(sol.shaped[c]) and float(jnp.std(sol.delta[c])) > 1e-6:
                corrs.append(np.corrcoef(np.asarray(sol.delta[c]),
                                         np.asarray(eta[c]))[0, 1])
    assert corrs, "no shaped clusters"
    assert np.mean(corrs) < -0.25, np.mean(corrs)


def test_daily_conservation_of_flexible_budget(fleet_run):
    cfg, st, recs = fleet_run
    for rec in recs:
        sol = rec["sol"]
        assert float(jnp.abs(sol.delta.sum(axis=1)).max()) < 1e-3


def test_vcc_within_machine_capacity(fleet_run):
    cfg, st, recs = fleet_run
    for rec in recs:
        assert bool(jnp.all(rec["vcc"] <= st.capacity[:, None] * 10.0
                            + 1e-3))
        sol = rec["sol"]
        shaped = np.asarray(sol.shaped)
        vccs = np.asarray(sol.vcc)[shaped]
        caps = np.asarray(st.capacity)[shaped]
        assert np.all(vccs <= caps[:, None] + 1e-3)


def test_inflexible_usage_untouched(fleet_run):
    """Shaping never reduces inflexible usage (it is always admitted)."""
    cfg, st, recs = fleet_run
    for rec in recs:
        res = rec["result"]
        assert bool(jnp.all(res.usage_total >= res.usage_flex - 1e-5))


def test_slo_violation_rate_controlled(fleet_run):
    cfg, st, recs = fleet_run
    from repro.core import slo
    rate = float(slo.violation_rate(st.slo_state).mean())
    assert rate <= 0.35            # early-operation bound; see benchmarks


def test_carbon_savings_vs_unshaped(fleet_run):
    """Shaped days should emit no more carbon during the dirtiest hours
    than the same load unshaped (weight power by intensity rank)."""
    cfg, st, recs = fleet_run
    dirty_shaped, dirty_flat = [], []
    for rec in recs:
        res, eta = rec["result"], rec["intensity"]
        shaped = np.asarray(rec["sol"].shaped)
        if not shaped.any():
            continue
        p = np.asarray(res.power)[shaped]
        e = np.asarray(eta)[shaped]
        top = e >= np.quantile(e, 0.75, axis=1, keepdims=True)
        dirty_shaped.append((p * top).sum() / p.sum())
        dirty_flat.append(top.mean())
    assert dirty_shaped, "no shaped clusters"
    # fraction of power spent in dirty hours < fraction of hours
    assert np.mean(dirty_shaped) <= np.mean(dirty_flat) + 0.01
