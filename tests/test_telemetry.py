"""Fleet telemetry layer tests (ISSUE 6 tentpole + satellites).

Four contracts:

* **Collapse** — ``StageConfig.telemetry=False`` (the default) compiles
  the day step to HLO byte-identical to the graph traced with the
  verbatim pre-telemetry ``solver.dual_ascent`` (so the golden trace and
  every parity test keep pinning the same executable), and the default
  ``StageConfig()`` equals an explicit ``telemetry=False``.
* **Parity** — batched telemetry == per-rollout sequential telemetry
  BITWISE (the DayTelemetry record rides the same batch-invariant
  numerics contract as the ledger; mirrors tests/test_stages_parity.py).
* **Export** — solve_vcc telemetry channels are sane, trace records
  round-trip through JSONL, ``report.telemetry_rows`` aggregates them,
  and ``report.scenario_rows`` uses the sample std (ddof=1; n=1 pins
  0.0, never NaN).

The hypothesis property tests for the calibration metric primitives
(coverage in [0, 1], MAPE >= 0, zero-error forecast => zero bias) live
in tests/test_telemetry_properties.py — a module-level importorskip
would otherwise skip THIS whole file where hypothesis is absent.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver, stages, vcc
from repro.sim import (SimConfig, build_batch, build_params,
                       default_library, init_ledger, ledger_update,
                       make_init, make_rollout, rollout_batch,
                       scenario_rows, telemetry_records, telemetry_rows,
                       write_jsonl, read_jsonl, DayTelemetry,
                       TELEMETRY_COLUMNS, TRACE_FIELDS, format_table)
from repro.sim import telemetry as T
from repro.sim.engine import _day_xs
from repro.sim.ledger import DayMetrics

CFG_KW = dict(n_clusters=4, n_campuses=2, n_zones=2, pds_per_cluster=2,
              hist_days=14)
DAYS = 2

f32 = jnp.float32


def _legacy_dual_ascent(inner, dual_update, x0, mu0, outer_iters):
    """Verbatim pre-telemetry ``solver.dual_ascent`` — the reference the
    collapse contract is certified against."""
    def outer(carry, _):
        x, mu = carry
        x = inner(x, mu)
        mu = dual_update(x, mu)
        return (x, mu), None

    (x, mu), _ = jax.lax.scan(outer, (x0, mu0), None, length=outer_iters)
    return x, mu


# ------------------------------------------------------- collapse contract

def test_default_stage_config_is_telemetry_off():
    assert stages.StageConfig().telemetry is False
    assert stages.StageConfig() == stages.StageConfig(telemetry=False)
    assert stages.StageConfig() != stages.StageConfig(telemetry=True)


def test_telemetry_off_day_step_hlo_byte_identical_to_legacy():
    """The telemetry=False day step must compile to EXACTLY the HLO of
    the graph traced with the pre-telemetry two-value dual-ascent scan —
    byte-equal text, not just numerics (the repo's collapse contract)."""
    cfg = SimConfig(**CFG_KW)
    sc = default_library(DAYS)[0]
    p = build_params(cfg, sc, 0, DAYS)
    s = jax.jit(make_init(cfg))(p)
    xs = _day_xs(p, 0)
    scfg = cfg.stage_config()
    step = jax.jit(stages.make_day_step(scfg))
    hlo_now = step.lower(p, s, xs).as_text()
    orig = solver.dual_ascent
    solver.dual_ascent = _legacy_dual_ascent
    try:
        hlo_legacy = jax.jit(stages.make_day_step(scfg)).lower(
            p, s, xs).as_text()
    finally:
        solver.dual_ascent = orig
    assert hlo_now == hlo_legacy


def test_solve_vcc_telemetry_off_hlo_identical():
    """Same contract one layer down: solve_vcc(telemetry=False) compiles
    byte-identical to the legacy solver graph."""
    p = vcc.synthetic_problem(6, seed=2)
    f = jax.jit(lambda q: vcc.solve_vcc(q, use_pallas=False))
    hlo_now = f.lower(p).as_text()
    orig = solver.dual_ascent
    solver.dual_ascent = _legacy_dual_ascent
    try:
        hlo_legacy = jax.jit(
            lambda q: vcc.solve_vcc(q, use_pallas=False)).lower(p).as_text()
    finally:
        solver.dual_ascent = orig
    assert hlo_now == hlo_legacy


def test_telemetry_off_traj_keys_unchanged():
    """telemetry=False must not grow the rollout traj (golden-trace key
    set); telemetry=True stacks DayTelemetry leaves under 'telemetry'."""
    cfg = SimConfig(**CFG_KW)
    sc = default_library(DAYS)[:1]
    batch = build_batch(cfg, sc, [0], DAYS)
    _, _, traj = rollout_batch(cfg, DAYS)(batch)
    assert "telemetry" not in traj
    cfg_on = SimConfig(**CFG_KW, telemetry=True)
    _, _, traj_on = rollout_batch(cfg_on, DAYS)(batch)
    tel = traj_on["telemetry"]
    assert isinstance(tel, DayTelemetry)
    assert tel.uif_mape.shape == (1, DAYS, CFG_KW["n_clusters"])


# ----------------------------------------------------------- bitwise parity

def test_batched_telemetry_matches_sequential_bitwise():
    """A vmap'd batch's DayTelemetry must reproduce each scenario's
    non-batched sequential rollout telemetry BITWISE — same contract,
    same idiom as tests/test_stages_parity.py for the ledger."""
    cfg = SimConfig(**CFG_KW, telemetry=True)
    scens = default_library(DAYS)[:3]
    batch = build_batch(cfg, scens, [0], DAYS)
    _, _, trajB = rollout_batch(cfg, DAYS)(batch)
    init = jax.jit(make_init(cfg))
    roll = jax.jit(make_rollout(cfg, DAYS))
    for i, sc in enumerate(scens):
        p = build_params(cfg, sc, 0, DAYS)
        _, _, traj = roll(p, init(p))
        for a, b in zip(jax.tree.leaves(trajB["telemetry"]),
                        jax.tree.leaves(traj["telemetry"])):
            np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b),
                                          err_msg=sc.name)


# ----------------------------------------------------- solver-channel sanity

def test_solve_vcc_telemetry_channels():
    """telemetry=True returns (sol, diag) with converging trajectories
    and near-zero residuals; the solution itself is bitwise the
    telemetry=False solution (the diagnostics only OBSERVE the scan)."""
    p = vcc.synthetic_problem(8, seed=5)
    sol0 = vcc.solve_vcc(p, use_pallas=False)
    sol, diag = vcc.solve_vcc(p, use_pallas=False, telemetry=True)
    np.testing.assert_array_equal(np.asarray(sol.delta),
                                  np.asarray(sol0.delta))
    n = p.tau.shape[0]
    assert diag["obj_cluster_traj"].shape == (20, n)
    assert diag["step_max_traj"].shape == (20, n)
    # PGD converges: the final step is much smaller than the first
    steps = np.asarray(diag["step_max_traj"]).max(axis=1)
    assert steps[-1] < steps[0]
    # conservation holds to projection tolerance at the solution
    assert float(np.max(np.asarray(diag["conservation_resid"]))) < 1e-3
    assert np.all(np.asarray(diag["proj_nu_tol"]) >= 0.0)
    # uncontended campus limits -> zero dual residual
    assert float(np.max(np.asarray(diag["dual_resid"]))) == 0.0
    # point-forecast problem -> degenerate tail mass 1.0
    np.testing.assert_array_equal(np.asarray(diag["cvar_tail_mass"]),
                                  np.ones(n, np.float32))


def test_day_step_telemetry_record_sane():
    """In-graph DayTelemetry gauges stay in range through a real rollout."""
    cfg = SimConfig(**CFG_KW, telemetry=True)
    sc = default_library(DAYS)[:1]
    batch = build_batch(cfg, sc, [0, 1], DAYS)
    _, _, traj = rollout_batch(cfg, DAYS)(batch)
    t = jax.tree.map(np.asarray, traj["telemetry"])
    for leaf in (t.uifq_coverage, t.vcc_binding_frac, t.theta_covered,
                 t.paused, t.shaped):
        assert np.all(leaf >= 0.0) and np.all(leaf <= 1.0)
    for leaf in (t.uif_mape, t.tuf_mape, t.tr_mape, t.queue_age_days,
                 t.fc_level_drift, t.proj_nu_tol, t.dual_resid,
                 t.cvar_tail_mass):
        assert np.all(leaf >= 0.0)
    assert np.all((t.joint_winner == 0.0) | (t.joint_winner == 1.0))


# ------------------------------------------------------------ trace export

def test_trace_records_roundtrip_jsonl(tmp_path):
    cfg = SimConfig(**CFG_KW, telemetry=True)
    scens = default_library(DAYS)[:2]
    batch = build_batch(cfg, scens, [0, 1], DAYS)
    _, _, traj = rollout_batch(cfg, DAYS)(batch)
    recs = telemetry_records(traj["telemetry"], [s.name for s in scens], 2)
    assert len(recs) == 2 * 2 * DAYS
    assert all(set(r) == set(TRACE_FIELDS) for r in recs)
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, recs)
    back = read_jsonl(path)
    assert back == json.loads(json.dumps(recs))  # exact round-trip
    rows = telemetry_rows(back)
    assert [r["scenario"] for r in rows] == [s.name for s in scens]
    table = format_table(rows, TELEMETRY_COLUMNS)
    assert "thetaCov" in table and "vccBind" in table
    # wrong batch geometry is rejected loudly
    with pytest.raises(ValueError):
        telemetry_records(traj["telemetry"], [scens[0].name], 2)


def test_profile_stages_rows(tmp_path):
    """The stage profiler attributes cost across the real stage list and
    its table renders (host-side satellite of the tentpole)."""
    cfg = SimConfig(**CFG_KW)
    sc = default_library(DAYS)[0]
    p = build_params(cfg, sc, 0, DAYS)
    s = jax.jit(make_init(cfg))(p)
    rows = T.profile_stages(cfg.stage_config(), p, s, reps=1)
    assert [r["stage"] for r in rows] == [
        "power_fit", "forecast", "carbon", "optimize", "observe",
        "day_step"]
    for r in rows:
        assert r["wall_ms"] > 0.0 and r["pct"] >= 0.0
    stage_pct = sum(r["pct"] for r in rows if r["stage"] != "day_step")
    assert abs(stage_pct - 100.0) < 1e-6
    table = T.format_stage_table(rows)
    assert "optimize" in table and "wall_ms" in table


# ------------------------------------------------------- report std fixes

def _ledger_batch(vals):
    """A batched one-cluster Ledger whose carbon_kg sums differ per seed."""
    leds = []
    for v in vals:
        led = init_ledger(1)
        m = DayMetrics(
            carbon_kg=jnp.asarray([v], f32), kwh=jnp.asarray([v], f32),
            peak_kw=jnp.asarray([1.0], f32), served=jnp.asarray([1.0], f32),
            arrived=jnp.asarray([1.0], f32), unmet=jnp.asarray([0.0], f32),
            queue_end=jnp.asarray([0.0], f32),
            cf_carbon_kg=jnp.asarray([2 * v], f32),
            cf_kwh=jnp.asarray([2 * v], f32),
            cf_peak_kw=jnp.asarray([2.0], f32),
            cf_served=jnp.asarray([1.0], f32),
            cf_queue_end=jnp.asarray([0.0], f32))
        leds.append(ledger_update(led, m))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *leds)


def test_scenario_rows_std_is_sample_std():
    """Seeds are a sample: std must be Bessel-corrected (ddof=1) for
    n_seeds > 1, and the n_seeds=1 path pins 0.0 — never NaN (np.std of
    one value with ddof=1 is NaN)."""
    led = _ledger_batch([10.0, 14.0])
    rows = scenario_rows(led, ["s"], n_seeds=2)
    vals = np.array([10.0, 14.0])
    assert rows[0]["carbon_kg"] == pytest.approx(vals.mean())
    assert rows[0]["carbon_kg_std"] == pytest.approx(vals.std(ddof=1))
    led1 = _ledger_batch([10.0])
    rows1 = scenario_rows(led1, ["s"], n_seeds=1)
    assert rows1[0]["carbon_kg_std"] == 0.0
    for k, v in rows1[0].items():
        if isinstance(v, float):
            assert not np.isnan(v), k
