"""Property tests for the telemetry calibration metric primitives.

``sim.telemetry.mape`` / ``bias`` / ``coverage`` / ``level_drift`` are
the in-graph forecast-calibration channels; these pin their algebraic
invariants over random inputs: coverage is a fraction in [0, 1], MAPE is
non-negative, a zero-error forecast has exactly zero bias, zero MAPE and
full coverage, and the drift gauge vanishes exactly at the trailing-
window mean it is measured against.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="capability check: the `hypothesis` package is not importable "
           "here; CI installs it (see .github/workflows/ci.yml) and runs "
           "these property tests under the fixed-seed 'ci' profile")
import hypothesis.extra.numpy as hnp  # noqa: E402
import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.admission import hour_sum  # noqa: E402
from repro.sim import telemetry as T  # noqa: E402

SET = dict(max_examples=25, deadline=None,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(
    pred=hnp.arrays(np.float32, (3, 24),
                    elements=st.floats(0.0, 50.0, width=32)),
    act=hnp.arrays(np.float32, (3, 24),
                   elements=st.floats(0.0, 50.0, width=32)),
)
@settings(**SET)
def test_coverage_in_unit_interval_and_mape_nonneg(pred, act):
    cov = np.asarray(T.coverage(jnp.asarray(pred), jnp.asarray(act)))
    assert np.all(cov >= 0.0) and np.all(cov <= 1.0)
    m = np.asarray(T.mape(jnp.asarray(pred), jnp.asarray(act)))
    assert np.all(m >= 0.0)


@given(
    act=hnp.arrays(np.float32, (4, 24),
                   elements=st.floats(0.1, 50.0, width=32)),
)
@settings(**SET)
def test_zero_error_forecast_zero_bias_zero_mape_full_coverage(act):
    a = jnp.asarray(act)
    np.testing.assert_array_equal(np.asarray(T.bias(a, a)),
                                  np.zeros(act.shape[0], np.float32))
    np.testing.assert_array_equal(np.asarray(T.mape(a, a)),
                                  np.zeros(act.shape[0], np.float32))
    # actual <= its own bound everywhere -> coverage exactly 1
    np.testing.assert_array_equal(np.asarray(T.coverage(a, a)),
                                  np.ones(act.shape[0], np.float32))


@given(
    trail=hnp.arrays(np.float32, (4, 7),
                     elements=st.floats(0.1, 50.0, width=32)),
)
@settings(**SET)
def test_level_drift_nonneg_and_zero_at_trailing_mean(trail):
    tr = jnp.asarray(trail)
    fc = 0.5 * (tr.min(axis=1) + tr.max(axis=1))
    d = np.asarray(T.level_drift(fc, tr))
    assert np.all(d >= 0.0)
    mean = T.level_drift(hour_sum(tr) / 7.0, tr)
    np.testing.assert_allclose(np.asarray(mean), 0.0, atol=1e-6)
