"""VCC optimizer: constraints, optimality vs exact reference, campus duals,
and the Pallas kernel path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vcc import (VCCProblem, delta_bounds,
                            greedy_linear_reference, solve_vcc)
from repro.kernels.vcc_pgd.kernel import pgd_epoch_pallas
from repro.kernels.vcc_pgd.ref import pgd_epoch_ref


def make_problem(n=6, lambda_p=0.0, seed=0, campus_limit=1e9):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    H = 24
    eta = 0.3 + 0.25 * jnp.sin(jnp.linspace(0, 2 * jnp.pi, H))[None] \
        + 0.05 * jax.random.normal(ks[0], (n, H))
    u_if = 0.4 + 0.05 * jax.random.normal(ks[1], (n, H))
    tau = 2.0 + 3.0 * jax.random.uniform(ks[2], (n,))
    pow_nom = 500.0 + 20.0 * jax.random.normal(ks[3], (n, H))
    pi = jnp.full((n, H), 300.0)
    return VCCProblem(
        eta=jnp.abs(eta), u_if=u_if, u_if_q=u_if * 1.1, tau=tau,
        pow_nom=pow_nom, pi=pi, u_pow_cap=jnp.full((n,), 0.95),
        capacity=jnp.full((n,), 1.3), ratio=jnp.full((n, H), 1.3),
        campus=jnp.asarray(np.arange(n) % 2, jnp.int32),
        campus_limit=jnp.full((2,), campus_limit),
        lambda_e=0.1, lambda_p=lambda_p, drop_limit=1.0)


def test_conservation_and_bounds():
    p = make_problem()
    sol = solve_vcc(p, inner_iters=120, outer_iters=3)
    lo, ub, feas = delta_bounds(p)
    assert bool(feas.all())
    assert float(jnp.abs(sol.delta.sum(1)).max()) < 1e-4
    assert bool(jnp.all(sol.delta >= lo - 1e-4))
    assert bool(jnp.all(sol.delta <= ub + 1e-4))
    assert bool(jnp.all(sol.vcc <= p.capacity[:, None] + 1e-4))


def test_matches_exact_greedy_when_linear():
    p = make_problem(lambda_p=0.0)
    sol = solve_vcc(p, inner_iters=250, outer_iters=2)
    lo, ub, _ = delta_bounds(p)
    for c in range(p.eta.shape[0]):
        cost = np.asarray(p.eta[c] * p.pi[c])
        dref = greedy_linear_reference(cost, np.asarray(lo[c]),
                                       np.asarray(ub[c]))
        jp = float((cost * np.asarray(sol.delta[c])).sum())
        jr = float((cost * dref).sum())
        assert jp <= jr + 0.005 * abs(jr), (c, jp, jr)


def test_peak_term_flattens_power():
    p0 = make_problem(lambda_p=0.0, seed=3)
    p1 = make_problem(lambda_p=5.0, seed=3)
    s0 = solve_vcc(p0, inner_iters=150, outer_iters=2)
    s1 = solve_vcc(p1, inner_iters=150, outer_iters=2)
    assert float(s1.y.mean()) <= float(s0.y.mean()) + 1e-3


def test_campus_duals_enforce_contract():
    p = make_problem(lambda_p=0.1, seed=4)
    unconstrained = solve_vcc(p, inner_iters=100, outer_iters=2)
    camp_peak = np.asarray(jax.ops.segment_sum(unconstrained.y, p.campus,
                                               num_segments=2))
    tight = make_problem(lambda_p=0.1, seed=4,
                         campus_limit=float(camp_peak.max()) * 0.97)
    sol = solve_vcc(tight, inner_iters=100, outer_iters=25)
    new_peak = np.asarray(jax.ops.segment_sum(sol.y, tight.campus,
                                              num_segments=2))
    viol = (new_peak - np.asarray(tight.campus_limit)) \
        / np.asarray(tight.campus_limit)
    assert viol.max() < 0.02, viol          # within 2% of the contract
    assert float(sol.mu.max()) > 0.0        # duals actually engaged


def test_pallas_epoch_matches_ref():
    n, H = 12, 24
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 6)
    delta = jnp.zeros((n, H))
    eta = 0.2 + 0.2 * jax.random.uniform(ks[0], (n, H))
    pi = 200 + 100 * jax.random.uniform(ks[1], (n, H))
    pow_nom = 400 + 100 * jax.random.uniform(ks[2], (n, H))
    tau24 = 0.05 + 0.2 * jax.random.uniform(ks[3], (n, 1))
    price = 0.05 * jnp.ones((n, 1))
    lo = jnp.full((n, H), -0.8)
    ub = 0.5 + jax.random.uniform(ks[4], (n, H))
    lr = 0.01 * jnp.ones((n, 1))
    kw = dict(temp=10.0, lambda_e=0.3, iters=30)
    d1 = pgd_epoch_ref(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                       **kw)
    d2 = pgd_epoch_pallas(delta, eta, pi, pow_nom, tau24, price, lo, ub, lr,
                          tile=8, interpret=True, **kw)
    assert float(jnp.abs(d1 - d2).max()) < 1e-5


def test_infeasible_clusters_get_capacity_vcc():
    p = make_problem(seed=6)
    # make cluster 0 hopeless: inflexible above the power cap all day
    u_if = p.u_if.at[0].set(2.0)
    p = VCCProblem(**{**p.__dict__, "u_if": u_if, "u_if_q": u_if * 1.1})
    sol = solve_vcc(p, inner_iters=50, outer_iters=2)
    assert not bool(sol.shaped[0])
    np.testing.assert_allclose(np.asarray(sol.vcc[0]),
                               float(p.capacity[0]), rtol=1e-5)
